//! Minimal JSON: recursive-descent parser + writer.
//!
//! Covers the subset this project exchanges (manifest.json, configs, bench
//! result files): objects, arrays, strings with standard escapes, f64
//! numbers, booleans, null. No serde in the offline crate set — see
//! DESIGN.md §2.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value (all numbers are f64, object keys are ordered).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key-sorted).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset into the input.
    pub pos: usize,
    /// What the parser expected.
    pub msg: String,
}

impl Json {
    // ------------------------------------------------------------ access
    /// Object field access (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access (`None` for non-arrays / out of range).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------- build
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a numeric value.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------------- parse
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------- write
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    /// Two-space-indented serialization.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"models":{"m":{"hlo":[{"bucket":256,"kind":"prefill"}],"rope_theta":10000.0,"ok":true,"x":null}}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(
            v.get("models").unwrap().get("m").unwrap().get("hlo").unwrap().idx(0)
                .unwrap().get("bucket").unwrap().as_usize(),
            Some(256)
        );
    }

    #[test]
    fn parses_nested_arrays_and_numbers() {
        let v = Json::parse("[1, -2.5, 3e2, [4]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_f64(), Some(300.0));
        assert_eq!(a[3].idx(0).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é中""#).unwrap();
        assert_eq!(v.as_str(), Some("é中"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_print_reparses() {
        let v = Json::obj(vec![
            ("a", Json::num(1)),
            ("b", Json::Arr(vec![Json::str("x"), Json::Bool(false)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
