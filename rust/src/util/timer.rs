//! Scoped wall-clock timing helpers used across benches and the engine.

use std::time::Instant;

/// Measure one closure, returning (result, seconds).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Repeat a closure and return per-iteration seconds (after warmup runs).
pub fn time_iters(warmup: usize, iters: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// A black-box sink preventing the optimizer from deleting bench bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_result_and_positive_duration() {
        let (v, secs) = time(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_iters_counts() {
        let mut n = 0;
        let ts = time_iters(2, 5, || n += 1);
        assert_eq!(ts.len(), 5);
        assert_eq!(n, 7);
    }
}
