//! Persistent dependency-driven work queue: the barrier-free executor
//! behind `--exec queue`.
//!
//! [`crate::util::threadpool::ThreadPool::scatter`] runs one *stage* at a
//! time and pays a full-pool barrier after each one: every fast item
//! waits for the stage's slowest straggler before the next stage may
//! start. This module replaces the stage sequence with a single
//! [`TaskGraph`] run — workers pull individual tasks from a shared ready
//! queue, and a task becomes ready the instant *its own* dependencies
//! complete, not when the whole batch finishes a stage. In the engine's
//! decode step that means sequence A's attention tasks run while
//! sequence B is still in QKV, and sequence A's layer 2 can start before
//! sequence B has finished layer 0.
//!
//! # Steady-state reuse
//!
//! A graph is a *reusable* object, not a per-step throwaway. Two levels
//! of reuse keep the warmed-up decode step allocation-free
//! (rust/tests/alloc.rs):
//!
//! * **Structure** — [`TaskGraph::clear`] resets the task list while
//!   keeping every edge list's capacity, so re-deriving the same shape
//!   re-allocates nothing; and when the shape is unchanged the caller
//!   can skip the rebuild entirely and re-run the cached structure
//!   (the decode graph cache, `--graph-cache`).
//! * **Run state** — pending counters, the ready queue, and the
//!   executor condvars live *in* the graph and are reset (not
//!   re-allocated) by every [`TaskGraph::run`]; the fan-out itself goes
//!   through [`crate::util::threadpool::ThreadPool::broadcast`], which
//!   posts one borrowed closure instead of boxing per-worker jobs.
//!
//! # Graph invariants
//!
//! The executor relies on four invariants; the first two are enforced by
//! construction, the last two are the caller's contract (the same
//! contract `scatter` already places on its items):
//!
//! 1. **Acyclic by construction.** [`TaskGraph::add`] only accepts
//!    dependencies on already-added tasks, so edges always point from a
//!    lower task id to a higher one — index order is a topological
//!    order, and cycles cannot be expressed.
//! 2. **Counter discipline.** Every task carries one atomic pending
//!    counter initialised to its dependency count; each completed
//!    dependency decrements it exactly once and the transition to zero
//!    enqueues the task exactly once. An observed underflow (a
//!    decrement past zero — only possible if the graph structures were
//!    corrupted) aborts the run with a panic instead of executing a
//!    task whose inputs may not exist.
//! 3. **Disjoint item state.** Tasks may share *reads*, but anything a
//!    task mutates must be untouched by every task not ordered with it
//!    by a dependency path. The executor never adds synchronization
//!    beyond the graph edges.
//! 4. **Worker arenas are overwrite-only.** Like `scatter`, each worker
//!    owns one `states` arena lent to whichever task it runs; a task
//!    must fully overwrite whatever it reads from the arena, so
//!    task→worker placement cannot affect results.
//!
//! Under invariants 3 and 4, *when* and *where* a task runs cannot change
//! what it computes — which is why `--exec queue` is bit-identical to the
//! barrier path for every thread count, batch shape and tile size.
//!
//! # Panic poisoning
//!
//! A panic inside a task is caught on the worker, the run is marked
//! poisoned, and no further tasks are dequeued (dependents of the dead
//! task never become ready, so draining would deadlock — the run aborts
//! instead). Once every in-flight task has retired, the panic is
//! re-raised on the caller thread; the pool itself stays usable.
//!
//! # Examples
//!
//! A diamond graph — `a` fans out to `b` and `c`, which join at `d`.
//! Dependencies are honoured regardless of worker count:
//!
//! ```
//! use std::sync::atomic::{AtomicUsize, Ordering};
//! use hata::util::threadpool::ThreadPool;
//! use hata::util::workqueue::TaskGraph;
//!
//! let mut g = TaskGraph::new();
//! let a = g.add(&[]);
//! let b = g.add(&[a]);
//! let c = g.add(&[a]);
//! let d = g.add(&[b, c]);
//!
//! // Each task records the global order in which it ran.
//! let clock = AtomicUsize::new(0);
//! let mut when = vec![0usize; g.len()];
//! let mut arenas = vec![(); 4]; // one scratch arena per worker
//! let pool = ThreadPool::new(4);
//! let stats = g.run(&pool, &mut when, &mut arenas, |_, slot, _| {
//!     *slot = clock.fetch_add(1, Ordering::SeqCst);
//! });
//!
//! assert_eq!(stats.tasks, 4);
//! assert!(when[a.index()] < when[b.index()]);
//! assert!(when[a.index()] < when[c.index()]);
//! assert!(when[d.index()] > when[b.index()]);
//! assert!(when[d.index()] > when[c.index()]);
//! ```

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::threadpool::ThreadPool;

/// Opaque handle to one task in a [`TaskGraph`], returned by
/// [`TaskGraph::add`] and consumed as a dependency by later `add` calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TaskId(usize);

impl TaskId {
    /// Index of this task's payload in the `items` slice passed to
    /// [`TaskGraph::run`] (tasks are numbered in `add` order).
    pub fn index(&self) -> usize {
        self.0
    }
}

/// How a [`TaskGraph::run`] aborted (recorded by workers, re-raised as a
/// panic on the caller thread).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Poison {
    /// A task panicked; its dependents can never run.
    TaskPanic,
    /// A pending counter was decremented past zero (corrupted graph).
    Underflow,
}

/// Ready-queue state guarded by the run mutex. Reused (cleared, not
/// re-allocated) across runs.
#[derive(Default)]
struct Ready {
    ready: VecDeque<usize>,
    finished: bool,
    poison: Option<Poison>,
}

/// Dependency graph of work items, executed with [`TaskGraph::run`].
/// Task ids double as indices into the payload slice handed to `run`.
///
/// Built once with [`TaskGraph::add`], runnable any number of times:
/// the executor's per-run state (pending counters, ready queue) is
/// embedded and reset in place, so repeated runs of a warmed graph
/// allocate nothing. [`TaskGraph::clear`] resets the structure while
/// keeping all buffer capacity for an in-place rebuild.
#[derive(Default)]
pub struct TaskGraph {
    /// Dependency count per task (pending-counter initial values).
    deps: Vec<usize>,
    /// Forward edges: tasks to notify when task `i` completes. May hold
    /// more entries than `deps` after a [`TaskGraph::clear`] + smaller
    /// rebuild; only the first `deps.len()` are live.
    children: Vec<Vec<usize>>,
    // ---- reusable executor state, reset by every `run` ----
    /// Atomic pending counters, one per task (grown on demand).
    pending: Vec<AtomicUsize>,
    /// Shared ready queue + finished/poison flags.
    queue: Mutex<Ready>,
    /// Wakes workers when tasks become ready (or the run finishes).
    cv: Condvar,
    /// Completed-task count for the current run.
    completed: AtomicUsize,
    /// Times a worker found the ready queue empty this run.
    idle_waits: AtomicUsize,
}

impl TaskGraph {
    /// Empty graph.
    pub fn new() -> Self {
        TaskGraph::default()
    }

    /// Empty graph with room for `n` tasks.
    pub fn with_capacity(n: usize) -> Self {
        TaskGraph {
            deps: Vec::with_capacity(n),
            children: Vec::with_capacity(n),
            ..TaskGraph::default()
        }
    }

    /// Reset the graph to empty while keeping every allocation — the
    /// outer task list, each task's edge list, and the executor's
    /// counters — so rebuilding a same-shaped (or smaller) graph
    /// performs no heap allocation.
    pub fn clear(&mut self) {
        self.deps.clear();
    }

    /// Add one task that may start once every task in `deps` has
    /// completed. Returns its id, which is also the index of its payload
    /// in the `items` slice given to [`TaskGraph::run`].
    ///
    /// Panics if a dependency id has not been added yet — edges always
    /// point backwards, which is what makes the graph acyclic by
    /// construction.
    pub fn add(&mut self, deps: &[TaskId]) -> TaskId {
        let id = self.deps.len();
        if id < self.children.len() {
            self.children[id].clear();
        } else {
            self.children.push(Vec::new());
        }
        for d in deps {
            assert!(d.0 < id, "workqueue: dependency {} of task {id} not added yet", d.0);
            self.children[d.0].push(id);
        }
        self.deps.push(deps.len());
        TaskId(id)
    }

    /// Number of tasks added since the last [`TaskGraph::clear`].
    pub fn len(&self) -> usize {
        self.deps.len()
    }

    /// True before the first [`TaskGraph::add`] (or right after a
    /// [`TaskGraph::clear`]).
    pub fn is_empty(&self) -> bool {
        self.deps.is_empty()
    }

    /// Execute every task on `pool`'s persistent workers, honouring the
    /// dependency edges: `f(id, &mut items[id], &mut states[worker])` is
    /// called exactly once per task, never before all of the task's
    /// dependencies have returned. Blocks until the whole graph has run.
    ///
    /// `items[i]` is task `i`'s payload; `items.len()` must equal
    /// [`TaskGraph::len`]. Like
    /// [`scatter`](crate::util::threadpool::ThreadPool::scatter), each
    /// worker gets exclusive use of one `states` arena, and the run
    /// degenerates to inline execution — in task-id order, which is a
    /// valid topological order by construction — when the pool, `states`
    /// or `items` has a single entry. Execution order beyond the edges
    /// is unspecified; under the module-level invariants it cannot
    /// affect results.
    ///
    /// Takes `&mut self` to reset the embedded run state in place; a
    /// warmed graph can be re-run any number of times without allocating
    /// (the dispatch itself goes through the pool's allocation-free
    /// [`broadcast`](crate::util::threadpool::ThreadPool::broadcast)).
    ///
    /// Panics if a task panicked (after the fan-out drains — the pool is
    /// not poisoned) or on a dependency-counter underflow.
    pub fn run<T, S, F>(
        &mut self,
        pool: &ThreadPool,
        items: &mut [T],
        states: &mut [S],
        f: F,
    ) -> QueueStats
    where
        T: Send,
        S: Send,
        F: Fn(usize, &mut T, &mut S) + Sync,
    {
        let n = self.deps.len();
        assert_eq!(items.len(), n, "workqueue: items must match graph size");
        let mut stats = QueueStats { runs: 1, tasks: n as u64, ..Default::default() };
        if n == 0 {
            return stats;
        }
        let width = pool.size().min(states.len()).min(n);
        if width <= 1 {
            // Task-id order is topological (edges point backwards), so the
            // inline path needs no counters and stays strictly serial.
            let s = states.first_mut().expect("workqueue: states must be non-empty");
            for (i, t) in items.iter_mut().enumerate() {
                f(i, t, s);
            }
            stats.inline_runs = 1;
            return stats;
        }
        // ---- reset the embedded run state in place (no allocation once
        // the graph has run at this size before)
        if self.pending.len() < n {
            let grow = n - self.pending.len();
            self.pending.reserve(grow);
            for _ in 0..grow {
                self.pending.push(AtomicUsize::new(0));
            }
        }
        for (p, &d) in self.pending.iter().zip(self.deps.iter()) {
            p.store(d, Ordering::Relaxed);
        }
        {
            let q = self.queue.get_mut().unwrap();
            q.ready.clear();
            // capacity for the worst case (every task ready at once) up
            // front: ready-queue growth must never depend on scheduling
            // jitter, or the zero-allocation guarantee would be flaky
            if q.ready.capacity() < n {
                q.ready.reserve(n);
            }
            q.ready.extend(self.deps.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i));
            q.finished = false;
            q.poison = None;
        }
        self.completed.store(0, Ordering::Relaxed);
        self.idle_waits.store(0, Ordering::Relaxed);
        let this: &TaskGraph = &*self;
        let items_addr = items.as_mut_ptr() as usize;
        let states_addr = states.as_mut_ptr() as usize;
        let f_ref = &f;
        // `broadcast` blocks until every participant returns, so all the
        // borrows the closure captures outlive every use on the workers.
        pool.broadcast(width, &|w: usize| {
            // SAFETY: `w` is unique per participant, so this is the only
            // &mut into states[w] for the whole run.
            let s = unsafe { &mut *(states_addr as *mut S).add(w) };
            this.drain(n, |i| {
                // SAFETY: the ready queue yields each task id exactly
                // once, so this &mut aliases no other task's payload.
                let t = unsafe { &mut *(items_addr as *mut T).add(i) };
                let guarded = AssertUnwindSafe(|| f_ref(i, t, &mut *s));
                std::panic::catch_unwind(guarded).is_ok()
            });
        });
        stats.idle_waits = self.idle_waits.load(Ordering::Relaxed) as u64;
        match self.queue.get_mut().unwrap().poison {
            Some(Poison::TaskPanic) => panic!("workqueue: a task panicked"),
            Some(Poison::Underflow) => panic!("workqueue: dependency counter underflow"),
            None => stats,
        }
    }

    /// Mark the run finished (success or poison) and wake everyone.
    fn finish(&self, poison: Option<Poison>) {
        let mut q = self.queue.lock().unwrap();
        if poison.is_some() && q.poison.is_none() {
            q.poison = poison;
        }
        q.finished = true;
        self.cv.notify_all();
    }

    /// Worker loop: pull ready tasks, run them via `exec` (returns false
    /// on panic), resolve dependents. Returns when the run finishes.
    fn drain(&self, n: usize, mut exec: impl FnMut(usize) -> bool) {
        loop {
            let task = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if q.finished {
                        break None;
                    }
                    if let Some(i) = q.ready.pop_front() {
                        break Some(i);
                    }
                    self.idle_waits.fetch_add(1, Ordering::Relaxed);
                    q = self.cv.wait(q).unwrap();
                }
            };
            let Some(i) = task else { return };
            if !exec(i) {
                // Dependents of a dead task can never become ready;
                // abort the drain instead of deadlocking on them.
                self.finish(Some(Poison::TaskPanic));
                return;
            }
            for &c in &self.children[i] {
                // AcqRel: the zero-observing worker must see everything
                // every dependency wrote before its decrement.
                let prev = self.pending[c].fetch_sub(1, Ordering::AcqRel);
                match prev {
                    0 => {
                        self.finish(Some(Poison::Underflow));
                        return;
                    }
                    1 => {
                        let mut q = self.queue.lock().unwrap();
                        q.ready.push_back(c);
                        self.cv.notify_one();
                    }
                    _ => {}
                }
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == n {
                self.finish(None);
                return;
            }
        }
    }
}

/// Executor counters from one or more [`TaskGraph::run`] calls — the
/// "how busy were the workers" signal the engine surfaces through
/// `coordinator::metrics`. Merge runs with [`QueueStats::merge`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Graph executions.
    pub runs: u64,
    /// Runs that degenerated to inline execution (single worker/arena).
    pub inline_runs: u64,
    /// Tasks executed across all runs.
    pub tasks: u64,
    /// Times a worker found the ready queue empty and blocked waiting
    /// for a dependency to resolve — the straggler/idle signal. High
    /// values relative to `tasks` mean the graph is starving the pool
    /// (batch too small, or one stage dominates).
    pub idle_waits: u64,
    /// Decode-graph structure (re)builds — batch shape changed, or the
    /// graph cache is off. Steady-state serving should see this stay
    /// flat while `graph_hits` grows.
    pub graph_builds: u64,
    /// Decode steps that reused the cached graph structure and only
    /// rebound task payloads in place (`--graph-cache on`).
    pub graph_hits: u64,
}

impl QueueStats {
    /// Accumulate another run's counters into this one.
    pub fn merge(&mut self, other: QueueStats) {
        self.runs += other.runs;
        self.inline_runs += other.inline_runs;
        self.tasks += other.tasks;
        self.idle_waits += other.idle_waits;
        self.graph_builds += other.graph_builds;
        self.graph_hits += other.graph_hits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_once_respecting_deps() {
        let mut g = TaskGraph::new();
        // 8 independent chains of length 5: a small batch-of-sequences shape
        let mut items: Vec<(u64, u64)> = Vec::new(); // (chain, step)
        for chain in 0..8u64 {
            let mut prev: Option<TaskId> = None;
            for step in 0..5u64 {
                let id = match prev {
                    Some(p) => g.add(&[p]),
                    None => g.add(&[]),
                };
                assert_eq!(id.index(), items.len());
                items.push((chain, step));
                prev = Some(id);
            }
        }
        let pool = ThreadPool::new(4);
        let mut states = vec![0u64; 4];
        let clock = AtomicU64::new(0);
        let mut payload: Vec<((u64, u64), u64)> = items.iter().map(|&c| (c, 0)).collect();
        let stats = g.run(&pool, &mut payload, &mut states, |_, p, s| {
            p.1 = clock.fetch_add(1, Ordering::SeqCst);
            *s += 1;
        });
        for (i, &((_, step), stamp)) in payload.iter().enumerate() {
            if step > 0 {
                assert!(stamp > payload[i - 1].1, "task {i} ran before its dependency");
            }
        }
        assert_eq!(stats.tasks, 40);
        assert_eq!(stats.runs, 1);
        assert_eq!(states.iter().sum::<u64>(), 40);
    }

    #[test]
    fn diamond_join_waits_for_both_branches() {
        for _ in 0..32 {
            let mut g = TaskGraph::new();
            let a = g.add(&[]);
            let b = g.add(&[a]);
            let c = g.add(&[a]);
            let d = g.add(&[b, c]);
            let pool = ThreadPool::new(3);
            let mut states = vec![(); 3];
            let clock = AtomicU64::new(0);
            let mut when = vec![0u64; g.len()];
            g.run(&pool, &mut when, &mut states, |_, w, _| {
                *w = clock.fetch_add(1, Ordering::SeqCst);
            });
            assert!(when[d.index()] > when[b.index()]);
            assert!(when[d.index()] > when[c.index()]);
            assert!(when[b.index()] > when[a.index()]);
            assert!(when[c.index()] > when[a.index()]);
        }
    }

    #[test]
    fn rerun_without_rebuild_matches_first_run() {
        // a warmed graph must be re-runnable in place: same structure,
        // fresh payloads, identical dependency behaviour every time
        let mut g = TaskGraph::new();
        let mut prev: Option<TaskId> = None;
        for _ in 0..6 {
            prev = Some(match prev {
                Some(p) => g.add(&[p]),
                None => g.add(&[]),
            });
        }
        let pool = ThreadPool::new(4);
        let mut states = vec![(); 4];
        for round in 0..5u64 {
            let mut items: Vec<u64> = vec![round; 6];
            let stats = g.run(&pool, &mut items, &mut states, |i, it, _| *it += i as u64);
            let want: Vec<u64> = (0..6).map(|i| round + i).collect();
            assert_eq!(items, want, "round {round}");
            assert_eq!(stats.tasks, 6);
        }
    }

    #[test]
    fn clear_and_rebuild_reuses_structure() {
        let mut g = TaskGraph::new();
        let a = g.add(&[]);
        let _ = g.add(&[a]);
        let _ = g.add(&[a]);
        assert_eq!(g.len(), 3);
        g.clear();
        assert!(g.is_empty());
        // rebuild a smaller graph; stale children of the old shape must
        // not leak into the new one
        let x = g.add(&[]);
        let y = g.add(&[x]);
        assert_eq!(g.len(), 2);
        let pool = ThreadPool::new(3);
        let mut states = vec![(); 3];
        let clock = AtomicU64::new(1);
        let mut when = vec![0u64; 2];
        let stats = g.run(&pool, &mut when, &mut states, |_, w, _| {
            *w = clock.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(stats.tasks, 2);
        assert!(when[y.index()] > when[x.index()]);
    }

    #[test]
    fn inline_when_single_worker_matches_pooled_results() {
        let mut g = TaskGraph::new();
        let mut prev = g.add(&[]);
        for _ in 0..9 {
            prev = g.add(&[prev]);
        }
        let mut run = |threads: usize| {
            let pool = ThreadPool::new(threads);
            let mut states = vec![0u64; threads];
            let mut items: Vec<u64> = (0..10).collect();
            let stats = g.run(&pool, &mut items, &mut states, |i, it, _| *it += i as u64);
            (items, stats.inline_runs)
        };
        let (serial, inline) = run(1);
        let (pooled, pooled_inline) = run(4);
        assert_eq!(serial, pooled);
        assert_eq!(inline, 1);
        assert_eq!(pooled_inline, 0);
    }

    #[test]
    #[should_panic(expected = "not added yet")]
    fn forward_dependency_rejected() {
        let mut g = TaskGraph::new();
        g.add(&[TaskId(3)]);
    }

    #[test]
    fn task_panic_poisons_run_but_not_pool() {
        let pool = ThreadPool::new(4);
        let mut g = TaskGraph::new();
        let a = g.add(&[]);
        let _b = g.add(&[a]);
        let _lone = g.add(&[]);
        let mut items = vec![0usize; 3];
        let mut states = vec![(); 4];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            g.run(&pool, &mut items, &mut states, |i, _, _| {
                if i == 0 {
                    panic!("boom");
                }
            });
        }));
        let err = r.expect_err("poisoned run must re-panic on the caller");
        let msg = panic_message(&err);
        assert!(msg.contains("task panicked"), "unexpected message: {msg}");
        // the pool survives: a fresh graph still runs to completion
        let mut g2 = TaskGraph::new();
        g2.add(&[]);
        g2.add(&[]);
        let mut items2 = vec![0u32; 2];
        let stats = g2.run(&pool, &mut items2, &mut states, |_, it, _| *it = 7);
        assert_eq!(items2, vec![7, 7]);
        assert_eq!(stats.tasks, 2);
    }

    #[test]
    fn dependency_counter_underflow_detected() {
        let pool = ThreadPool::new(4);
        // Corrupt a graph on purpose: task 1 is listed as a child of both
        // roots but claims only one dependency, so the second decrement
        // underflows. Unreachable through the builder API (which keeps
        // counts and edges consistent) — this exercises the guard rail.
        let mut g = TaskGraph::new();
        let a = g.add(&[]);
        let b = g.add(&[]);
        let c = g.add(&[a]);
        g.children[b.0].push(c.0); // edge without a matching count
        let mut items = vec![0usize; 3];
        let mut states = vec![(); 4];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            g.run(&pool, &mut items, &mut states, |_, _, _| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
        }));
        // Whichever of the two parents resolves its edge second observes
        // the counter already at zero, so the guard always trips.
        let err = r.expect_err("underflow must abort the run");
        let msg = panic_message(&err);
        assert!(msg.contains("underflow"), "unexpected message: {msg}");
    }

    /// Extract the &str/String payload of a caught panic.
    fn panic_message(err: &(dyn std::any::Any + Send)) -> String {
        err.downcast_ref::<&'static str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default()
    }

    #[test]
    fn empty_graph_is_noop() {
        let mut g = TaskGraph::new();
        let pool = ThreadPool::new(2);
        let mut items: Vec<usize> = Vec::new();
        let mut states = vec![(); 2];
        let stats = g.run(&pool, &mut items, &mut states, |_, _, _| {});
        assert_eq!(stats.tasks, 0);
        assert!(g.is_empty());
    }

    #[test]
    fn stats_merge_accumulates_all_fields() {
        let mut a = QueueStats {
            runs: 1,
            inline_runs: 0,
            tasks: 10,
            idle_waits: 2,
            graph_builds: 1,
            graph_hits: 0,
        };
        a.merge(QueueStats {
            runs: 1,
            inline_runs: 1,
            tasks: 5,
            idle_waits: 0,
            graph_builds: 0,
            graph_hits: 1,
        });
        assert_eq!(a.runs, 2);
        assert_eq!(a.inline_runs, 1);
        assert_eq!(a.tasks, 15);
        assert_eq!(a.idle_waits, 2);
        assert_eq!(a.graph_builds, 1);
        assert_eq!(a.graph_hits, 1);
    }
}
