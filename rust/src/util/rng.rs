//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! All randomness in benches, workload generators and property tests flows
//! through this so every table/figure regenerates bit-identically.

/// xoshiro256++ (Blackman & Vigna). Not cryptographic; fast and splittable
/// enough for workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a stream (SplitMix64-expanded state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-thread / per-request use).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next raw 32-bit output (high word of [`Rng::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fill with standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(5);
        let picks = r.choose_distinct(100, 40);
        assert_eq!(picks.len(), 40);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(picks.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
