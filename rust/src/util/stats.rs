//! Summary statistics for benches and serving metrics.

/// Online mean/min/max accumulator plus retained samples for percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    /// Empty accumulator.
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (0 with fewer than two samples).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = p / 100.0 * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 99th percentile.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for the
/// serving hot path where retaining every sample would be allocation noise.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [base * 2^(i/4), base * 2^((i+1)/4)) seconds
    counts: Vec<u64>,
    base: f64,
    total: u64,
    sum: f64,
}

impl LatencyHistogram {
    /// Empty histogram (base 1us, quarter-octave buckets up to ~1000s).
    pub fn new() -> Self {
        LatencyHistogram { counts: vec![0; 120], base: 1e-6, total: 0, sum: 0.0 }
    }

    /// Record one latency sample.
    pub fn record(&mut self, seconds: f64) {
        let idx = if seconds <= self.base {
            0
        } else {
            (((seconds / self.base).log2() * 4.0) as usize).min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of all recorded samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Upper edge of the bucket containing the given quantile.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * 2f64.powf((i + 1) as f64 / 4.0);
            }
        }
        self.base * 2f64.powf(self.counts.len() as f64 / 4.0)
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let mut h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(0.001); // 1ms
        }
        let q = h.quantile(0.99);
        assert!(q >= 0.001 && q < 0.002, "q={q}");
        assert!((h.mean() - 0.001).abs() < 1e-9);
    }

    #[test]
    fn histogram_orders_quantiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-4);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }
}
