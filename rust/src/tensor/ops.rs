//! Scalar reference implementations of the numeric primitives shared by
//! the native transformer and baselines.
//!
//! These run on raw slices so the decode loop allocates nothing; see
//! EXPERIMENTS.md §Perf for the optimization history. They are also the
//! *bit-exact reference* for the runtime-dispatched SIMD backends in
//! [`crate::tensor::simd`]: every reduction here follows a canonical
//! lane decomposition (16-element blocks split into two 8-lane
//! accumulator groups, merged lane-wise and summed in a fixed order)
//! that the vector paths reproduce instruction for instruction, so
//! `KernelMode::Simd` output is bitwise equal to these loops.

/// Lane-parallel block width shared with the SIMD backends: 16 elements
/// = two 8-lane (AVX2-width) accumulator groups.
pub(crate) const BLOCK: usize = 16;

/// y += A[row] dot products: `y[j] = sum_i x[i] * a[i, j]` for A [n, m].
/// (vector–matrix product, the decode-time projection shape x @ W).
///
/// Each output element `y[j]` is an independent sequential accumulation
/// over rows `i`, which makes any lane-width vectorization of the inner
/// loop bit-identical to this scalar form. The historical
/// `if xi == 0.0 { continue; }` sparsity skip was removed: it cost a
/// branch per row on dense inputs and blocked straight-line
/// vectorization (microbench table in docs/PERFORMANCE.md §--kernels).
pub fn vecmat(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(y.len(), m);
    y.fill(0.0);
    // row-major A: accumulate row-by-row, which is sequential in memory.
    for (i, &xi) in x.iter().enumerate() {
        let row = &a[i * m..(i + 1) * m];
        for (yj, &aij) in y.iter_mut().zip(row) {
            *yj += xi * aij;
        }
    }
}

/// C = A @ B for row-major A [n, k], B [k, m] -> C [n, m] (ikj order —
/// one [`vecmat`] per output row, same per-element accumulation order).
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(c.len(), n * m);
    for i in 0..n {
        vecmat(&a[i * k..(i + 1) * k], b, m, &mut c[i * m..(i + 1) * m]);
    }
}

/// dot(a, b) in the canonical lane-decomposed order: 16-element blocks
/// into a 16-wide accumulator array (autovectorizes well), two 8-lane
/// halves merged element-wise, an ordered left-to-right horizontal sum,
/// then the scalar tail. The SIMD backends perform exactly this
/// sequence with two 8-lane vector accumulators, so their result is
/// bit-identical.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let blocks = n / BLOCK;
    let mut acc = [0.0f32; BLOCK];
    for i in 0..blocks {
        let x = &a[i * BLOCK..i * BLOCK + BLOCK];
        let y = &b[i * BLOCK..i * BLOCK + BLOCK];
        for ((av, &xv), &yv) in acc.iter_mut().zip(x).zip(y) {
            *av += xv * yv;
        }
    }
    // lane merge (acc0 + acc1 in the vector paths) ...
    let mut lane = [0.0f32; BLOCK / 2];
    let (lo, hi) = acc.split_at(BLOCK / 2);
    for ((l, &a0), &a1) in lane.iter_mut().zip(lo).zip(hi) {
        *l = a0 + a1;
    }
    // ... then the ordered horizontal reduction and the scalar tail.
    let mut s = lane[0];
    for &l in &lane[1..] {
        s += l;
    }
    for i in blocks * BLOCK..n {
        s += a[i] * b[i];
    }
    s
}

/// In-place numerically-stable softmax.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: y = x / rms(x) * g. The mean square reuses the canonical
/// [`dot`] reduction (`dot(x, x)`) so the SIMD path matches bitwise.
pub fn rms_norm(x: &[f32], g: &[f32], y: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let ms = dot(x, x) / n;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((yi, &xi), &gi) in y.iter_mut().zip(x).zip(g) {
        *yi = xi * inv * gi;
    }
}

/// Rotary position embedding, matching python/compile/model.py `rope`:
/// pairs (x[i], x[i + half]) rotated by angle pos * theta^(-i/half).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let dh = x.len();
    let half = dh / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// argmax over a slice (first max wins).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecmat_matches_naive() {
        let x = [1.0, 2.0, 3.0];
        let a = [1.0, 0.0, 0.0, 1.0, 2.0, 0.0]; // [3, 2]
        let mut y = [0.0; 2];
        vecmat(&x, &a, 2, &mut y);
        assert_eq!(y, [1.0 + 6.0, 2.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.0, 2.0, 3.0, 4.0]; // [2,2]
        let eye = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &eye, 2, 2, 2, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn dot_matches_reference() {
        for n in [3, 13, 16, 17, 32, 100] {
            let a: Vec<f32> = (0..n).map(|x| x as f32 * 0.25).collect();
            let b: Vec<f32> = (0..n).map(|x| (x * 2) as f32 * 0.5).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-3 * want.abs().max(1.0), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0, 1001.0, 999.0];
        softmax(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn rms_norm_unit_gain() {
        let x = [3.0, 4.0];
        let g = [1.0, 1.0];
        let mut y = [0.0; 2];
        rms_norm(&x, &g, &mut y, 0.0);
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-6);
        assert!((y[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rope_pos0_is_identity() {
        let mut x = vec![0.5, -1.0, 2.0, 3.0];
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
