//! Numeric primitives shared by the native transformer and baselines.
//!
//! These run on raw slices so the decode loop allocates nothing; see
//! EXPERIMENTS.md §Perf for the optimization history.

/// y += A[row] dot products: `y[j] = sum_i x[i] * a[i, j]` for A [n, m].
/// (vector–matrix product, the decode-time projection shape x @ W).
pub fn vecmat(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    let n = x.len();
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(y.len(), m);
    y.fill(0.0);
    // row-major A: accumulate row-by-row, which is sequential in memory.
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &a[i * m..(i + 1) * m];
        for (yj, &aij) in y.iter_mut().zip(row) {
            *yj += xi * aij;
        }
    }
}

/// C = A @ B for row-major A [n, k], B [k, m] -> C [n, m] (ikj order).
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(c.len(), n * m);
    c.fill(0.0);
    for i in 0..n {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * m..(p + 1) * m];
            let crow = &mut c[i * m..(i + 1) * m];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aip * bj;
            }
        }
    }
}

/// dot(a, b) with 4-way unrolling (autovectorizes well).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let (x, y) = (&a[i * 4..i * 4 + 4], &b[i * 4..i * 4 + 4]);
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// In-place numerically-stable softmax.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm: y = x / rms(x) * g.
pub fn rms_norm(x: &[f32], g: &[f32], y: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let ms = x.iter().map(|v| v * v).sum::<f32>() / n;
    let inv = 1.0 / (ms + eps).sqrt();
    for ((yi, &xi), &gi) in y.iter_mut().zip(x).zip(g) {
        *yi = xi * inv * gi;
    }
}

/// Rotary position embedding, matching python/compile/model.py `rope`:
/// pairs (x[i], x[i + half]) rotated by angle pos * theta^(-i/half).
pub fn rope_inplace(x: &mut [f32], pos: usize, theta: f32) {
    let dh = x.len();
    let half = dh / 2;
    for i in 0..half {
        let freq = theta.powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// argmax over a slice (first max wins).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecmat_matches_naive() {
        let x = [1.0, 2.0, 3.0];
        let a = [1.0, 0.0, 0.0, 1.0, 2.0, 0.0]; // [3, 2]
        let mut y = [0.0; 2];
        vecmat(&x, &a, 2, &mut y);
        assert_eq!(y, [1.0 + 6.0, 2.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = [1.0, 2.0, 3.0, 4.0]; // [2,2]
        let eye = [1.0, 0.0, 0.0, 1.0];
        let mut c = [0.0; 4];
        matmul(&a, &eye, 2, 2, 2, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn dot_matches_reference() {
        let a: Vec<f32> = (0..13).map(|x| x as f32).collect();
        let b: Vec<f32> = (0..13).map(|x| (x * 2) as f32).collect();
        let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0, 1001.0, 999.0];
        softmax(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn rms_norm_unit_gain() {
        let x = [3.0, 4.0];
        let g = [1.0, 1.0];
        let mut y = [0.0; 2];
        rms_norm(&x, &g, &mut y, 0.0);
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-6);
        assert!((y[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn rope_pos0_is_identity() {
        let mut x = vec![0.5, -1.0, 2.0, 3.0];
        let orig = x.clone();
        rope_inplace(&mut x, 0, 10000.0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_inplace(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-5);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
