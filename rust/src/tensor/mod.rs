//! Dense f32 tensors + the numeric primitives the native engine uses.
//!
//! The serving hot path works on raw `&[f32]` slices with explicit dims
//! (no shape bookkeeping per decode step); `Tensor` carries shapes for
//! weight storage, goldens and tests. `io` loads `.npz` checkpoints with
//! a self-contained reader (no external crates).

pub mod io;
pub mod ops;
pub mod simd;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Wrap data with a shape (element count must match).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Dimension sizes.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Flat row-major payload.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat row-major payload.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for zero-element tensors.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Flatten leading dims: view as [rows, cols] where cols = last dim.
    pub fn as_matrix(&self) -> (usize, usize, &[f32]) {
        let cols = *self.shape.last().expect("scalar tensor");
        (self.data.len() / cols, cols, &self.data)
    }

    /// Strict reshape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Element [a, b, c, d] of a 4-D tensor.
    pub fn index4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        let s = &self.shape;
        assert_eq!(s.len(), 4);
        self.data[((a * s[1] + b) * s[2] + c) * s[3] + d]
    }

    /// Contiguous slice `[b, c, :]` of a 4-D tensor at index [a, b, c, :].
    pub fn slice4(&self, a: usize, b: usize, c: usize) -> &[f32] {
        let s = &self.shape;
        assert_eq!(s.len(), 4);
        let off = ((a * s[1] + b) * s[2] + c) * s[3];
        &self.data[off..off + s[3]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.row(1), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn slice4_addresses_correctly() {
        let data: Vec<f32> = (0..2 * 3 * 4 * 5).map(|x| x as f32).collect();
        let t = Tensor::new(vec![2, 3, 4, 5], data);
        assert_eq!(t.slice4(1, 2, 3)[0], t.index4(1, 2, 3, 0));
        assert_eq!(t.slice4(0, 0, 0), &[0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[3, 2]);
    }
}
