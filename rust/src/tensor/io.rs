//! `.npz` checkpoint loading via the `xla` crate's npy reader.
//!
//! The Python build path saves everything as f32 or i32 (the xla 0.5.1
//! npy reader has no unsigned-32 descr); packed hash codes travel as i32
//! bit patterns and are reinterpreted on this side.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use super::Tensor;

/// A named array loaded from an .npz: f32 or i32 payload.
#[derive(Clone, Debug)]
pub enum Array {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Array {
    pub fn shape(&self) -> &[usize] {
        match self {
            Array::F32(t) => t.shape(),
            Array::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Array::F32(t) => Ok(t),
            Array::I32 { .. } => bail!("array is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Array::I32 { data, .. } => Ok(data),
            Array::F32(_) => bail!("array is f32, expected i32"),
        }
    }

    /// Reinterpret an i32 payload as packed u32 hash-code words.
    pub fn as_u32(&self) -> Result<Vec<u32>> {
        Ok(self.as_i32()?.iter().map(|&x| x as u32).collect())
    }
}

/// All arrays of one .npz file, by name.
#[derive(Debug, Default)]
pub struct TensorStore {
    arrays: BTreeMap<String, Array>,
}

impl TensorStore {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let lits = xla::Literal::read_npz(path, &())
            .with_context(|| format!("reading npz {}", path.display()))?;
        let mut arrays = BTreeMap::new();
        for (name, lit) in lits {
            let shape: Vec<usize> = lit
                .array_shape()
                .context("npz entry has no array shape")?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            let arr = match lit.ty()? {
                xla::ElementType::F32 => {
                    Array::F32(Tensor::new(shape, lit.to_vec::<f32>()?))
                }
                xla::ElementType::S32 => Array::I32 { shape, data: lit.to_vec::<i32>()? },
                xla::ElementType::F64 => {
                    let v: Vec<f64> = lit.to_vec()?;
                    Array::F32(Tensor::new(shape, v.into_iter().map(|x| x as f32).collect()))
                }
                xla::ElementType::S64 => {
                    let v: Vec<i64> = lit.to_vec()?;
                    Array::I32 { shape, data: v.into_iter().map(|x| x as i32).collect() }
                }
                other => bail!("unsupported npz dtype {other:?} for {name}"),
            };
            arrays.insert(name, arr);
        }
        Ok(TensorStore { arrays })
    }

    pub fn get(&self, name: &str) -> Result<&Array> {
        self.arrays
            .get(name)
            .with_context(|| format!("npz missing array {name:?}"))
    }

    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        self.get(name)?.as_f32()
    }

    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.get(name)?.as_i32()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.arrays.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrays.is_empty()
    }
}
