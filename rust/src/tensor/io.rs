//! `.npz` checkpoint loading — a self-contained reader (zip central
//! directory + NPY headers), no external crates.
//!
//! The Python build path saves with `np.savez` (STORED zip entries, no
//! compression), everything as f32 or i32; packed hash codes travel as
//! i32 bit patterns and are reinterpreted on this side. 64-bit payloads
//! (numpy's default int/float) are narrowed on load.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::Tensor;

/// A named array loaded from an .npz: f32 or i32 payload.
#[derive(Clone, Debug)]
pub enum Array {
    /// Float payload (f4/f8 sources, f8 narrowed).
    F32(Tensor),
    /// Integer payload (i4/u4/i8/u8 sources, 64-bit narrowed).
    I32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major payload.
        data: Vec<i32>,
    },
}

impl Array {
    /// Dimension sizes regardless of dtype.
    pub fn shape(&self) -> &[usize] {
        match self {
            Array::F32(t) => t.shape(),
            Array::I32 { shape, .. } => shape,
        }
    }

    /// The float tensor, or an error for integer payloads.
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Array::F32(t) => Ok(t),
            Array::I32 { .. } => bail!("array is i32, expected f32"),
        }
    }

    /// The integer payload, or an error for float payloads.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Array::I32 { data, .. } => Ok(data),
            Array::F32(_) => bail!("array is f32, expected i32"),
        }
    }

    /// Reinterpret an i32 payload as packed u32 hash-code words.
    pub fn as_u32(&self) -> Result<Vec<u32>> {
        Ok(self.as_i32()?.iter().map(|&x| x as u32).collect())
    }
}

// ---------------------------------------------------------------- zip

fn rd_u16(b: &[u8], at: usize) -> u16 {
    u16::from_le_bytes([b[at], b[at + 1]])
}

fn rd_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]])
}

/// One stored zip member: (name, payload range into the archive bytes).
fn zip_entries(bytes: &[u8]) -> Result<Vec<(String, std::ops::Range<usize>)>> {
    const EOCD_SIG: u32 = 0x0605_4b50;
    const CENTRAL_SIG: u32 = 0x0201_4b50;
    const LOCAL_SIG: u32 = 0x0403_4b50;
    ensure!(bytes.len() >= 22, "zip too small");
    // EOCD: scan back over a possible trailing comment (<= 64 KiB)
    let mut eocd = None;
    let lo = bytes.len().saturating_sub(22 + 65_536);
    for at in (lo..=bytes.len() - 22).rev() {
        if rd_u32(bytes, at) == EOCD_SIG {
            eocd = Some(at);
            break;
        }
    }
    let eocd = eocd.context("zip end-of-central-directory not found")?;
    let count = rd_u16(bytes, eocd + 10) as usize;
    let mut at = rd_u32(bytes, eocd + 16) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        ensure!(at + 46 <= bytes.len() && rd_u32(bytes, at) == CENTRAL_SIG, "bad central entry");
        let method = rd_u16(bytes, at + 10);
        let comp_size = rd_u32(bytes, at + 20) as usize;
        let uncomp_size = rd_u32(bytes, at + 24) as usize;
        let name_len = rd_u16(bytes, at + 28) as usize;
        let extra_len = rd_u16(bytes, at + 30) as usize;
        let comment_len = rd_u16(bytes, at + 32) as usize;
        let local_off = rd_u32(bytes, at + 42) as usize;
        ensure!(
            comp_size != u32::MAX as usize && local_off != u32::MAX as usize,
            "zip64 archives unsupported"
        );
        ensure!(
            at + 46 + name_len + extra_len + comment_len <= bytes.len(),
            "truncated central directory entry"
        );
        let name = std::str::from_utf8(&bytes[at + 46..at + 46 + name_len])
            .context("non-utf8 zip member name")?
            .to_string();
        ensure!(
            method == 0,
            "zip member {name:?} is compressed (method {method}); np.savez writes stored entries"
        );
        ensure!(comp_size == uncomp_size, "stored zip member with mismatched sizes");
        // local header gives the real data offset (its name/extra fields
        // can differ in length from the central copy)
        ensure!(
            local_off + 30 <= bytes.len() && rd_u32(bytes, local_off) == LOCAL_SIG,
            "bad local header for {name:?}"
        );
        let lname = rd_u16(bytes, local_off + 26) as usize;
        let lextra = rd_u16(bytes, local_off + 28) as usize;
        let data_at = local_off + 30 + lname + lextra;
        ensure!(data_at + comp_size <= bytes.len(), "zip member {name:?} out of bounds");
        out.push((name, data_at..data_at + comp_size));
        at += 46 + name_len + extra_len + comment_len;
    }
    Ok(out)
}

// ---------------------------------------------------------------- npy

/// Parse one .npy payload into an [`Array`].
fn parse_npy(name: &str, b: &[u8]) -> Result<Array> {
    ensure!(b.len() >= 10 && &b[..6] == b"\x93NUMPY", "{name}: not an npy payload");
    let (major, _minor) = (b[6], b[7]);
    let (header_len, header_at) = if major == 1 {
        (rd_u16(b, 8) as usize, 10)
    } else {
        ensure!(b.len() >= 12, "{name}: truncated npy header");
        (rd_u32(b, 8) as usize, 12)
    };
    ensure!(header_at + header_len <= b.len(), "{name}: truncated npy header");
    let header = std::str::from_utf8(&b[header_at..header_at + header_len])
        .with_context(|| format!("{name}: non-ascii npy header"))?;
    let descr = dict_str(header, "descr").with_context(|| format!("{name}: npy descr"))?;
    let fortran = dict_raw(header, "fortran_order")
        .map(|v| v.starts_with("True"))
        .unwrap_or(false);
    ensure!(!fortran, "{name}: fortran_order arrays unsupported");
    let shape = dict_shape(header).with_context(|| format!("{name}: npy shape"))?;
    let n: usize = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .with_context(|| format!("{name}: npy shape overflows"))?;
    let data = &b[header_at + header_len..];
    let elem = |width: usize| -> Result<()> {
        let need = n
            .checked_mul(width)
            .with_context(|| format!("{name}: npy size overflows"))?;
        ensure!(data.len() >= need, "{name}: npy payload too short");
        Ok(())
    };
    // accept native/little markers; the build path never writes big-endian
    let d = descr.trim_start_matches(['<', '=', '|']);
    Ok(match d {
        "f4" => {
            elem(4)?;
            let v: Vec<f32> = data
                .chunks_exact(4)
                .take(n)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Array::F32(Tensor::new(shape, v))
        }
        "f8" => {
            elem(8)?;
            let v: Vec<f32> = data
                .chunks_exact(8)
                .take(n)
                .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32)
                .collect();
            Array::F32(Tensor::new(shape, v))
        }
        "i4" | "u4" => {
            elem(4)?;
            let v: Vec<i32> = data
                .chunks_exact(4)
                .take(n)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Array::I32 { shape, data: v }
        }
        "i8" | "u8" => {
            elem(8)?;
            let v: Vec<i32> = data
                .chunks_exact(8)
                .take(n)
                .map(|c| {
                    i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as i32
                })
                .collect();
            Array::I32 { shape, data: v }
        }
        other => bail!("{name}: unsupported npy dtype {other:?}"),
    })
}

/// Extract a quoted string value from the npy header dict.
fn dict_str(header: &str, key: &str) -> Option<String> {
    let raw = dict_raw(header, key)?;
    let raw = raw.trim_start();
    let quote = raw.chars().next()?;
    if quote != '\'' && quote != '"' {
        return None;
    }
    let rest = &raw[1..];
    Some(rest[..rest.find(quote)?].to_string())
}

/// Raw text following `'key':` in the npy header dict.
fn dict_raw<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)?;
    Some(header[at + pat.len()..].trim_start())
}

fn dict_shape(header: &str) -> Option<Vec<usize>> {
    let raw = dict_raw(header, "shape")?;
    let open = raw.find('(')?;
    let close = raw.find(')')?;
    let inner = &raw[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(part.parse().ok()?);
    }
    Some(shape)
}

/// All arrays of one .npz file, by name (the `.npy` member suffix is
/// stripped).
#[derive(Debug, Default)]
pub struct TensorStore {
    arrays: BTreeMap<String, Array>,
}

impl TensorStore {
    /// Read and parse every member of one .npz archive.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes =
            std::fs::read(path).with_context(|| format!("reading npz {}", path.display()))?;
        let mut arrays = BTreeMap::new();
        for (name, range) in
            zip_entries(&bytes).with_context(|| format!("parsing npz {}", path.display()))?
        {
            let key = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            let arr = parse_npy(&name, &bytes[range])
                .with_context(|| format!("parsing npz {}", path.display()))?;
            arrays.insert(key, arr);
        }
        Ok(TensorStore { arrays })
    }

    /// Array by name (error when missing).
    pub fn get(&self, name: &str) -> Result<&Array> {
        self.arrays
            .get(name)
            .with_context(|| format!("npz missing array {name:?}"))
    }

    /// Float tensor by name.
    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        self.get(name)?.as_f32()
    }

    /// Integer payload by name.
    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.get(name)?.as_i32()
    }

    /// All array names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.arrays.keys().map(|s| s.as_str())
    }

    /// Array count.
    pub fn len(&self) -> usize {
        self.arrays.len()
    }

    /// True when the archive held no arrays.
    pub fn is_empty(&self) -> bool {
        self.arrays.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal npy payload builder (v1 header, little-endian).
    fn npy(descr: &str, shape: &[usize], payload: &[u8]) -> Vec<u8> {
        let shape_txt = match shape.len() {
            0 => "()".to_string(),
            1 => format!("({},)", shape[0]),
            _ => format!("({})", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")),
        };
        let header =
            format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_txt}, }}\n");
        let mut out = Vec::new();
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(payload);
        out
    }

    /// Minimal stored-entry zip builder (the shape np.savez writes).
    fn zip(entries: &[(&str, Vec<u8>)]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut central = Vec::new();
        for (name, data) in entries {
            let offset = out.len() as u32;
            out.extend_from_slice(&0x0403_4b50u32.to_le_bytes());
            out.extend_from_slice(&20u16.to_le_bytes()); // version
            out.extend_from_slice(&[0; 2]); // flags
            out.extend_from_slice(&[0; 2]); // method: stored
            out.extend_from_slice(&[0; 4]); // time+date
            out.extend_from_slice(&[0; 4]); // crc (unchecked)
            out.extend_from_slice(&(data.len() as u32).to_le_bytes());
            out.extend_from_slice(&(data.len() as u32).to_le_bytes());
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(&[0; 2]); // extra len
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(data);
            central.extend_from_slice(&0x0201_4b50u32.to_le_bytes());
            central.extend_from_slice(&20u16.to_le_bytes());
            central.extend_from_slice(&20u16.to_le_bytes());
            central.extend_from_slice(&[0; 2]); // flags
            central.extend_from_slice(&[0; 2]); // method
            central.extend_from_slice(&[0; 4]); // time+date
            central.extend_from_slice(&[0; 4]); // crc
            central.extend_from_slice(&(data.len() as u32).to_le_bytes());
            central.extend_from_slice(&(data.len() as u32).to_le_bytes());
            central.extend_from_slice(&(name.len() as u16).to_le_bytes());
            central.extend_from_slice(&[0; 2]); // extra len
            central.extend_from_slice(&[0; 2]); // comment len
            central.extend_from_slice(&[0; 2]); // disk
            central.extend_from_slice(&[0; 2]); // int attrs
            central.extend_from_slice(&[0; 4]); // ext attrs
            central.extend_from_slice(&offset.to_le_bytes());
            central.extend_from_slice(name.as_bytes());
        }
        let cd_offset = out.len() as u32;
        out.extend_from_slice(&central);
        out.extend_from_slice(&0x0605_4b50u32.to_le_bytes());
        out.extend_from_slice(&[0; 4]); // disk numbers
        out.extend_from_slice(&(entries.len() as u16).to_le_bytes());
        out.extend_from_slice(&(entries.len() as u16).to_le_bytes());
        out.extend_from_slice(&(central.len() as u32).to_le_bytes());
        out.extend_from_slice(&cd_offset.to_le_bytes());
        out.extend_from_slice(&[0; 2]); // comment len
        out
    }

    fn le_f32(v: &[f32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    fn le_i64(v: &[i64]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    #[test]
    fn roundtrips_savez_shaped_archive() {
        let bytes = zip(&[
            ("weights.npy", npy("<f4", &[2, 2], &le_f32(&[1.0, 2.0, 3.0, 4.0]))),
            ("codes.npy", npy("<i8", &[3], &le_i64(&[7, -1, 2]))),
        ]);
        let dir = std::env::temp_dir().join("hata_io_test.npz");
        std::fs::write(&dir, &bytes).unwrap();
        let store = TensorStore::load(&dir).unwrap();
        assert_eq!(store.len(), 2);
        let w = store.f32("weights").unwrap();
        assert_eq!(w.shape(), &[2, 2]);
        assert_eq!(w.data(), &[1.0, 2.0, 3.0, 4.0]);
        let c = store.i32("codes").unwrap();
        assert_eq!(c, &[7, -1, 2]);
        // i32 reinterprets as u32 bit patterns
        assert_eq!(store.get("codes").unwrap().as_u32().unwrap()[1], u32::MAX);
        assert!(store.f32("missing").is_err());
        assert!(store.f32("codes").is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("hata_io_garbage.npz");
        std::fs::write(&dir, b"not a zip at all").unwrap();
        assert!(TensorStore::load(&dir).is_err());
    }
}
