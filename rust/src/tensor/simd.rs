//! Runtime-dispatched SIMD f32 kernels (`--kernels`, ROADMAP item 3).
//!
//! Every primitive here comes in three tiers selected by [`KernelMode`]:
//!
//! * `Reference` — the scalar loops in [`crate::tensor::ops`], which fix
//!   the canonical accumulation order (16-element blocks, two 8-lane
//!   accumulator groups, ordered horizontal sum).
//! * `Simd` (default) — explicit 8-lane AVX2 (x86_64) or 4-lane NEON
//!   (aarch64) kernels that replay the *same* per-element operation
//!   sequence: lane-parallel multiply-then-add with the reference's
//!   lane merge and ordered horizontal reduction, never a fused
//!   multiply-add and never a reassociated sum. Output is bit-identical
//!   to `Reference` on every input (asserted across the whole engine
//!   matrix in `rust/tests/parallel.rs`).
//! * `SimdFma` — the documented fast-math tier: fused multiply-add
//!   contractions and a vectorized polynomial `exp`. Results differ
//!   from the reference by bounded ULPs (FMA keeps the intermediate
//!   product in full precision, so reductions are *more* accurate, and
//!   the degree-6 `exp` polynomial is within a few ULP of libm); the
//!   equivalence tests below bound the error against f64 accumulation.
//!
//! Dispatch is resolved once per process from CPU features
//! (`is_x86_feature_detected!`) and cached; `HATA_SIMD=scalar` in the
//! environment forces the scalar fallback so both dispatch paths stay
//! testable on any host (the CI matrix runs one leg this way). When no
//! vector backend is available, `Simd` and `SimdFma` silently fall back
//! to the reference loops — `Simd` is bit-identical anyway, and the
//! fallback keeps aarch64-without-NEON and other targets correct.

use crate::tensor::ops;

/// Which f32 kernel implementation tier the engine uses (`--kernels`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Scalar canonical-order reference loops ([`crate::tensor::ops`]).
    Reference,
    /// Explicit-lane SIMD, bit-identical to `Reference` (the default).
    #[default]
    Simd,
    /// SIMD with fused multiply-add and polynomial `exp`: fast-math
    /// tier, ULP-bounded (not bitwise) equivalence to `Reference`.
    SimdFma,
}

impl KernelMode {
    /// Parse a CLI value (`reference` | `simd` | `simd-fma`).
    pub fn parse(s: &str) -> Option<KernelMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "reference" | "ref" | "scalar" => KernelMode::Reference,
            "simd" => KernelMode::Simd,
            "simd-fma" | "simdfma" | "fma" => KernelMode::SimdFma,
            _ => return None,
        })
    }

    /// Canonical lowercase name (CLI value, bench row label).
    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Reference => "reference",
            KernelMode::Simd => "simd",
            KernelMode::SimdFma => "simd-fma",
        }
    }

    /// All modes, for bench/test sweeps.
    pub fn all() -> [KernelMode; 3] {
        [KernelMode::Reference, KernelMode::Simd, KernelMode::SimdFma]
    }
}

// ------------------------------------------------------------ KV dtype

/// Storage datatype of cached K/V rows (`--kv-dtype`).
///
/// Half-precision rows are stored *packed*: two 16-bit elements per f32
/// storage slot, so a logical `head_dim`-element row occupies
/// [`KvDtype::elems`]`(head_dim) = head_dim / 2` slots inside the same
/// `Vec<f32>` arenas the f32 layout uses (which is what makes every
/// byte count — block planes, spill buffers, transfer ledgers — halve
/// without touching the plumbing). Quantization (round-to-nearest-even)
/// happens exactly once, on append; every read widens exactly, so a
/// stored row round-trips bit-for-bit and `Reference`/`Simd` reads stay
/// bit-identical per dtype. Hash codes and the other selector side
/// structures are always built from the pre-quantization f32 key row,
/// so top-k *selection* is unaffected by the storage dtype.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// Full-precision f32 storage (the default; bit-identical to the
    /// historical layout).
    #[default]
    F32,
    /// bfloat16: f32 truncated to an 8-bit mantissa with RNE rounding.
    /// Same exponent range as f32, ~2-3 decimal digits.
    Bf16,
    /// IEEE binary16: 10-bit mantissa, narrow exponent (|x| <~ 65504,
    /// subnormals below ~6e-5).
    F16,
}

impl KvDtype {
    /// Parse a CLI value (`f32` | `bf16` | `f16`).
    pub fn parse(s: &str) -> Option<KvDtype> {
        Some(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => KvDtype::F32,
            "bf16" | "bfloat16" => KvDtype::Bf16,
            "f16" | "fp16" | "half" | "float16" => KvDtype::F16,
            _ => return None,
        })
    }

    /// Canonical lowercase name (CLI value, bench row label).
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Bf16 => "bf16",
            KvDtype::F16 => "f16",
        }
    }

    /// All dtypes, for bench/test sweeps.
    pub fn all() -> [KvDtype; 3] {
        [KvDtype::F32, KvDtype::Bf16, KvDtype::F16]
    }

    /// Bytes per stored element (4 or 2) — the factor the offload
    /// ledger and roofline byte counts scale by.
    pub const fn bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Bf16 | KvDtype::F16 => 2,
        }
    }

    /// f32 storage slots occupied by a logical `dh`-element row (`dh`
    /// for f32, `dh / 2` packed for the half dtypes; half storage
    /// requires an even `dh`, asserted where caches are built).
    #[inline]
    pub fn elems(self, dh: usize) -> usize {
        match self {
            KvDtype::F32 => dh,
            KvDtype::Bf16 | KvDtype::F16 => {
                debug_assert_eq!(dh % 2, 0, "half KV dtypes need even head_dim");
                dh / 2
            }
        }
    }

    /// True for the packed 16-bit dtypes.
    pub const fn is_half(self) -> bool {
        !matches!(self, KvDtype::F32)
    }
}

// ---------------------------------------------- half-precision scalars

/// f32 -> bf16 with round-to-nearest-even (NaN kept quiet, sign kept).
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// bf16 -> f32 (exact: the bit pattern is the f32 high half).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 -> IEEE f16 with round-to-nearest-even, overflow to infinity,
/// gradual underflow through f16 subnormals, NaN kept quiet.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // Inf stays Inf; NaN maps to a quiet NaN with the payload head.
        return if abs > 0x7F80_0000 {
            sign | 0x7E00 | ((abs >> 13) as u16 & 0x03FF)
        } else {
            sign | 0x7C00
        };
    }
    let e = (abs >> 23) as i32 - 127 + 15; // rebias 8-bit -> 5-bit
    if e >= 31 {
        return sign | 0x7C00; // overflow -> Inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        // subnormal: shift the full 24-bit significand into place, RNE
        let man = (abs & 0x7F_FFFF) | 0x80_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            half + (((rem > halfway) as u32) | (((rem == halfway) as u32) & (half & 1)));
        return sign | rounded as u16;
    }
    let man = abs & 0x7F_FFFF;
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    // rounding may carry into the exponent; e == 30 carrying to 0x7C00
    // is exactly the RNE overflow-to-Inf case.
    let rounded = half + (((rem > 0x1000) as u32) | (((rem == 0x1000) as u32) & (half & 1)));
    sign | rounded as u16
}

/// IEEE f16 -> f32 (exact; matches the F16C `vcvtph2ps` widening).
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            if man == 0 {
                f32::from_bits(sign)
            } else {
                // subnormal: value = man * 2^-24, exact in f32
                let v = man as f32 * (1.0 / 16_777_216.0);
                f32::from_bits(v.to_bits() | sign)
            }
        }
        0x1F => {
            if man == 0 {
                f32::from_bits(sign | 0x7F80_0000)
            } else {
                f32::from_bits(sign | 0x7FC0_0000 | (man << 13))
            }
        }
        e => f32::from_bits(sign | ((e as u32 + 112) << 23) | (man << 13)),
    }
}

/// Widen one stored 16-bit element of `dtype` to f32 (exact).
#[inline]
pub fn widen1(dtype: KvDtype, h: u16) -> f32 {
    match dtype {
        KvDtype::F32 => unreachable!("f32 rows are not packed"),
        KvDtype::Bf16 => bf16_to_f32(h),
        KvDtype::F16 => f16_to_f32(h),
    }
}

// ------------------------------------------------------ packed row I/O

/// View a packed half-precision arena as its `u16` elements (element
/// `i` of a row is the `i`-th `u16` in memory order; both the pack and
/// widen paths go through this view, so the layout is endian-agnostic).
#[inline]
pub(crate) fn packed_u16(p: &[f32]) -> &[u16] {
    // SAFETY: u16 alignment is below f32's and the byte span is equal.
    unsafe { std::slice::from_raw_parts(p.as_ptr() as *const u16, p.len() * 2) }
}

/// Mutable variant of [`packed_u16`].
#[inline]
pub(crate) fn packed_u16_mut(p: &mut [f32]) -> &mut [u16] {
    // SAFETY: as packed_u16; the borrow is exclusive.
    unsafe { std::slice::from_raw_parts_mut(p.as_mut_ptr() as *mut u16, p.len() * 2) }
}

/// Quantize one logical f32 row into packed storage
/// (`dst.len() == dtype.elems(src.len())`; RNE per element, the single
/// lossy step of the half-KV pipeline).
pub fn pack_row(dtype: KvDtype, src: &[f32], dst: &mut [f32]) {
    match dtype {
        KvDtype::F32 => dst.copy_from_slice(src),
        KvDtype::Bf16 | KvDtype::F16 => {
            let d = packed_u16_mut(dst);
            debug_assert_eq!(d.len(), src.len());
            for (o, &x) in d.iter_mut().zip(src) {
                *o = if dtype == KvDtype::Bf16 { f32_to_bf16(x) } else { f32_to_f16(x) };
            }
        }
    }
}

/// Append one quantized logical row onto a packed arena (the contiguous
/// cache's `extend_from_slice` equivalent; resizes within reserved
/// capacity, so the steady-state append path stays allocation-free).
pub fn pack_extend(dtype: KvDtype, src: &[f32], dst: &mut Vec<f32>) {
    match dtype {
        KvDtype::F32 => dst.extend_from_slice(src),
        _ => {
            let at = dst.len();
            dst.resize(at + dtype.elems(src.len()), 0.0);
            pack_row(dtype, src, &mut dst[at..]);
        }
    }
}

/// Widen one packed storage row back to logical f32 (exact;
/// `dst.len() * dtype.bytes() == src.len() * 4`).
pub fn widen_row(dtype: KvDtype, src: &[f32], dst: &mut [f32]) {
    match dtype {
        KvDtype::F32 => dst.copy_from_slice(src),
        KvDtype::Bf16 | KvDtype::F16 => {
            let s = packed_u16(src);
            debug_assert_eq!(s.len(), dst.len());
            for (o, &h) in dst.iter_mut().zip(s) {
                *o = widen1(dtype, h);
            }
        }
    }
}

/// Append the exactly-widened row onto an f32 gather buffer (the
/// sparse gather path's `extend_from_slice` equivalent).
pub fn widen_extend(dtype: KvDtype, src: &[f32], dst: &mut Vec<f32>) {
    match dtype {
        KvDtype::F32 => dst.extend_from_slice(src),
        _ => {
            let at = dst.len();
            dst.resize(at + src.len() * 2, 0.0);
            widen_row(dtype, src, &mut dst[at..]);
        }
    }
}

/// Vector backend resolved at runtime (one cached probe per process).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2 { fma: bool, f16c: bool },
    #[cfg(target_arch = "aarch64")]
    Neon,
}

fn detect_backend() -> Backend {
    if let Ok(v) = std::env::var("HATA_SIMD") {
        let v = v.to_ascii_lowercase();
        if v == "scalar" || v == "off" || v == "0" {
            return Backend::Scalar;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Backend::Avx2 {
                fma: std::arch::is_x86_feature_detected!("fma"),
                f16c: std::arch::is_x86_feature_detected!("f16c"),
            };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Backend::Neon;
    }
    #[allow(unreachable_code)]
    Backend::Scalar
}

fn backend() -> Backend {
    static CACHE: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();
    *CACHE.get_or_init(detect_backend)
}

/// Human-readable name of the active vector backend (bench headers,
/// `--verbose` logs): `"avx2+fma"`, `"avx2"`, `"neon"` or `"scalar"`.
/// F16C only gates the f16 widening fast path internally and does not
/// change the name (the set of names is a stable contract).
pub fn backend_name() -> &'static str {
    match backend() {
        Backend::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 { fma: true, .. } => "avx2+fma",
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 { fma: false, .. } => "avx2",
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => "neon",
    }
}

/// True when an explicit vector backend (AVX2 / NEON) is active rather
/// than the scalar fallback. The integer popcount kernels in
/// [`crate::attention::hamming`] key their `KernelMode` dispatch off
/// this, mirroring how the float kernels fall back when `HATA_SIMD`
/// forces scalar.
pub(crate) fn lanes_active() -> bool {
    backend() != Backend::Scalar
}

/// True when `mode` will actually run the fused-multiply-add polynomial
/// kernels on this host (SimdFma requested and AVX2+FMA detected).
#[cfg(target_arch = "x86_64")]
fn fma_active(mode: KernelMode) -> bool {
    mode == KernelMode::SimdFma && matches!(backend(), Backend::Avx2 { fma: true, .. })
}

// ------------------------------------------------------------------ dot

/// Mode-dispatched dot product. `Reference`/`Simd` are bit-identical
/// (canonical [`ops::dot`] order); `SimdFma` contracts with FMA.
#[inline]
pub fn dot(mode: KernelMode, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match mode {
        KernelMode::Reference => ops::dot(a, b),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::dot_avx2(a, b) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::dot_neon(a, b) },
            _ => ops::dot(a, b),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true, .. } => unsafe { x86::dot_fma(a, b) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false, .. } => unsafe { x86::dot_avx2(a, b) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::dot_fma_neon(a, b) },
            _ => ops::dot(a, b),
        },
    }
}

// --------------------------------------------------------------- vecmat

/// Mode-dispatched vector–matrix product `y[j] = sum_i x[i] * a[i, j]`
/// (the decode projection shape). Lane-parallel per output element, so
/// `Simd` is bit-identical to [`ops::vecmat`] at any lane width.
pub fn vecmat(mode: KernelMode, x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), x.len() * m);
    debug_assert_eq!(y.len(), m);
    match mode {
        KernelMode::Reference => ops::vecmat(x, a, m, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::vecmat_avx2(x, a, m, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::vecmat_neon(x, a, m, y) },
            _ => ops::vecmat(x, a, m, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true, .. } => unsafe { x86::vecmat_fma(x, a, m, y) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false, .. } => unsafe { x86::vecmat_avx2(x, a, m, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::vecmat_fma_neon(x, a, m, y) },
            _ => ops::vecmat(x, a, m, y),
        },
    }
}

/// Mode-dispatched matmul: one [`vecmat`] per output row (the reference
/// ikj order), C = A @ B for row-major A [n, k], B [k, m] -> C [n, m].
pub fn matmul(mode: KernelMode, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(c.len(), n * m);
    for i in 0..n {
        vecmat(mode, &a[i * k..(i + 1) * k], b, m, &mut c[i * m..(i + 1) * m]);
    }
}

// ----------------------------------------------------------------- axpy

/// y += alpha * x (the attention `o += p * v` row update). One
/// independent multiply-then-add per element, so every lane width is
/// bit-identical; `SimdFma` contracts to `fmadd`.
#[inline]
pub fn axpy(mode: KernelMode, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match mode {
        KernelMode::Reference => axpy_scalar(alpha, x, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::axpy_avx2(alpha, x, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::axpy_neon(alpha, x, y) },
            _ => axpy_scalar(alpha, x, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true, .. } => unsafe { x86::axpy_fma(alpha, x, y) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false, .. } => unsafe { x86::axpy_avx2(alpha, x, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::axpy_fma_neon(alpha, x, y) },
            _ => axpy_scalar(alpha, x, y),
        },
    }
}

fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yj, &xj) in y.iter_mut().zip(x) {
        *yj += alpha * xj;
    }
}

// ----------------------------------------------------- widening kernels
//
// The half-KV read path: each kernel takes the packed storage row and
// widens elements to f32 *in-register* (AVX2 integer widen for bf16,
// F16C `vcvtph2ps` for f16, `vmovl`+shift on NEON) before the exact
// same arithmetic as its f32 counterpart. Widening is exact, so the
// scalar references below are bit-identical to the vector paths per
// dtype — the same contract the f32 kernels keep — and `KvDtype::F32`
// simply delegates to the f32 kernel.

/// Scalar reference for [`dot_wide`]: [`ops::dot`]'s canonical blocked
/// order with each packed element widened before the multiply.
fn dot_wide_scalar(dtype: KvDtype, a: &[f32], h: &[u16]) -> f32 {
    let n = a.len();
    const B: usize = ops::BLOCK;
    let blocks = n / B;
    let mut acc = [0.0f32; B];
    for i in 0..blocks {
        for (j, av) in acc.iter_mut().enumerate() {
            *av += a[i * B + j] * widen1(dtype, h[i * B + j]);
        }
    }
    let mut lane = [0.0f32; B / 2];
    let (lo, hi) = acc.split_at(B / 2);
    for ((l, &a0), &a1) in lane.iter_mut().zip(lo).zip(hi) {
        *l = a0 + a1;
    }
    let mut s = lane[0];
    for &l in &lane[1..] {
        s += l;
    }
    for i in blocks * B..n {
        s += a[i] * widen1(dtype, h[i]);
    }
    s
}

/// Mode-dispatched dot of an f32 query row against a packed K row of
/// `dtype` (`packed.len() == dtype.elems(a.len())`). `KvDtype::F32` is
/// exactly [`dot`]; the half dtypes widen in-register and keep
/// `Reference`/`Simd` bit-identical per dtype. On x86 the f16 fast path
/// needs F16C (universal on AVX2-era cores); without it the scalar
/// reference runs, which is bit-identical anyway.
#[inline]
pub fn dot_wide(mode: KernelMode, dtype: KvDtype, a: &[f32], packed: &[f32]) -> f32 {
    if dtype == KvDtype::F32 {
        return dot(mode, a, packed);
    }
    let h = packed_u16(packed);
    debug_assert_eq!(h.len(), a.len());
    match mode {
        KernelMode::Reference => dot_wide_scalar(dtype, a, h),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { f16c, .. } => match dtype {
                KvDtype::Bf16 => unsafe { x86::dot_wide_bf16_avx2(a, h) },
                KvDtype::F16 if f16c => unsafe { x86::dot_wide_f16_avx2(a, h) },
                _ => dot_wide_scalar(dtype, a, h),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => unsafe { neon::dot_wide_bf16_neon(a, h) },
            _ => dot_wide_scalar(dtype, a, h),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma, f16c } => match dtype {
                KvDtype::Bf16 if fma => unsafe { x86::dot_wide_bf16_fma(a, h) },
                KvDtype::Bf16 => unsafe { x86::dot_wide_bf16_avx2(a, h) },
                KvDtype::F16 if fma && f16c => unsafe { x86::dot_wide_f16_fma(a, h) },
                KvDtype::F16 if f16c => unsafe { x86::dot_wide_f16_avx2(a, h) },
                _ => dot_wide_scalar(dtype, a, h),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => {
                unsafe { neon::dot_wide_bf16_fma_neon(a, h) }
            }
            _ => dot_wide_scalar(dtype, a, h),
        },
    }
}

/// Scalar reference for [`axpy_wide`] (elementwise, so every lane width
/// is bit-identical by construction).
fn axpy_wide_scalar(dtype: KvDtype, alpha: f32, h: &[u16], y: &mut [f32]) {
    for (yj, &hj) in y.iter_mut().zip(h) {
        *yj += alpha * widen1(dtype, hj);
    }
}

/// y += alpha * widen(x) over a packed V row of `dtype` (the attention
/// `o += p * v` update against half-precision storage). `KvDtype::F32`
/// is exactly [`axpy`].
#[inline]
pub fn axpy_wide(mode: KernelMode, dtype: KvDtype, alpha: f32, packed: &[f32], y: &mut [f32]) {
    if dtype == KvDtype::F32 {
        return axpy(mode, alpha, packed, y);
    }
    let h = packed_u16(packed);
    debug_assert_eq!(h.len(), y.len());
    match mode {
        KernelMode::Reference => axpy_wide_scalar(dtype, alpha, h, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { f16c, .. } => match dtype {
                KvDtype::Bf16 => unsafe { x86::axpy_wide_bf16_avx2(alpha, h, y) },
                KvDtype::F16 if f16c => unsafe { x86::axpy_wide_f16_avx2(alpha, h, y) },
                _ => axpy_wide_scalar(dtype, alpha, h, y),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => {
                unsafe { neon::axpy_wide_bf16_neon(alpha, h, y) }
            }
            _ => axpy_wide_scalar(dtype, alpha, h, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma, f16c } => match dtype {
                KvDtype::Bf16 if fma => unsafe { x86::axpy_wide_bf16_fma(alpha, h, y) },
                KvDtype::Bf16 => unsafe { x86::axpy_wide_bf16_avx2(alpha, h, y) },
                KvDtype::F16 if fma && f16c => unsafe { x86::axpy_wide_f16_fma(alpha, h, y) },
                KvDtype::F16 if f16c => unsafe { x86::axpy_wide_f16_avx2(alpha, h, y) },
                _ => axpy_wide_scalar(dtype, alpha, h, y),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => {
                unsafe { neon::axpy_wide_bf16_fma_neon(alpha, h, y) }
            }
            _ => axpy_wide_scalar(dtype, alpha, h, y),
        },
    }
}

/// Scalar reference for [`vecmat_wide`]: row-major accumulation, the
/// [`ops::vecmat`] order with each matrix element widened first.
fn vecmat_wide_scalar(dtype: KvDtype, x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        let row = &h[i * m..(i + 1) * m];
        for (yj, &hij) in y.iter_mut().zip(row) {
            *yj += xi * widen1(dtype, hij);
        }
    }
}

/// Mode-dispatched vector–matrix product against a packed row-major
/// matrix of `dtype`: `y[j] = sum_i x[i] * widen(a[i, j])` for a
/// logical A `[x.len(), m]` (`packed.len() == dtype.elems(x.len() * m)`,
/// requiring an even `m` so packed rows stay slot-aligned).
/// `KvDtype::F32` is exactly [`vecmat`]. Per output element the
/// accumulation is sequential in `i`, so every lane width is
/// bit-identical to the scalar reference.
pub fn vecmat_wide(
    mode: KernelMode,
    dtype: KvDtype,
    x: &[f32],
    packed: &[f32],
    m: usize,
    y: &mut [f32],
) {
    if dtype == KvDtype::F32 {
        return vecmat(mode, x, packed, m, y);
    }
    let h = packed_u16(packed);
    debug_assert_eq!(m % 2, 0, "packed vecmat rows need an even m");
    debug_assert_eq!(h.len(), x.len() * m);
    debug_assert_eq!(y.len(), m);
    match mode {
        KernelMode::Reference => vecmat_wide_scalar(dtype, x, h, m, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { f16c, .. } => match dtype {
                KvDtype::Bf16 => unsafe { x86::vecmat_wide_bf16_avx2(x, h, m, y) },
                KvDtype::F16 if f16c => unsafe { x86::vecmat_wide_f16_avx2(x, h, m, y) },
                _ => vecmat_wide_scalar(dtype, x, h, m, y),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => {
                unsafe { neon::vecmat_wide_bf16_neon(x, h, m, y) }
            }
            _ => vecmat_wide_scalar(dtype, x, h, m, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma, f16c } => match dtype {
                KvDtype::Bf16 if fma => unsafe { x86::vecmat_wide_bf16_fma(x, h, m, y) },
                KvDtype::Bf16 => unsafe { x86::vecmat_wide_bf16_avx2(x, h, m, y) },
                KvDtype::F16 if fma && f16c => unsafe { x86::vecmat_wide_f16_fma(x, h, m, y) },
                KvDtype::F16 if f16c => unsafe { x86::vecmat_wide_f16_avx2(x, h, m, y) },
                _ => vecmat_wide_scalar(dtype, x, h, m, y),
            },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon if dtype == KvDtype::Bf16 => {
                unsafe { neon::vecmat_wide_bf16_fma_neon(x, h, m, y) }
            }
            _ => vecmat_wide_scalar(dtype, x, h, m, y),
        },
    }
}

// ---------------------------------------------------------------- scale

/// x *= alpha in place (softmax normalization pass). Lane-parallel,
/// bit-identical at any width.
#[inline]
pub fn scale(mode: KernelMode, x: &mut [f32], alpha: f32) {
    match mode {
        KernelMode::Reference => scale_scalar(x, alpha),
        _ => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::scale_avx2(x, alpha) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::scale_neon(x, alpha) },
            _ => scale_scalar(x, alpha),
        },
    }
}

fn scale_scalar(x: &mut [f32], alpha: f32) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

// ------------------------------------------------------------- rms_norm

/// Mode-dispatched RMSNorm `y = x / rms(x) * g`. The mean square is the
/// canonical [`dot`]`(x, x)` reduction; the normalization pass computes
/// `(x[i] * inv) * g[i]` per element in every tier.
pub fn rms_norm(mode: KernelMode, x: &[f32], g: &[f32], y: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let ms = dot(mode, x, x) / n;
    let inv = 1.0 / (ms + eps).sqrt();
    match mode {
        KernelMode::Reference => rms_apply_scalar(x, g, y, inv),
        _ => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::rms_apply_avx2(x, g, y, inv) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::rms_apply_neon(x, g, y, inv) },
            _ => rms_apply_scalar(x, g, y, inv),
        },
    }
}

fn rms_apply_scalar(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
    for ((yi, &xi), &gi) in y.iter_mut().zip(x).zip(g) {
        *yi = xi * inv * gi;
    }
}

// ------------------------------------------------------------- softmax

/// Streaming-softmax exponential pass: `x[t] = exp(x[t] - max)` in
/// place, returning the sum of the exponentials (the denominator).
/// `Reference` and `Simd` run the identical sequential scalar loop —
/// `exp` stays libm and the sum order is fixed, preserving bit
/// equality — while `SimdFma` batches a degree-6 polynomial `exp`
/// across lanes with a reassociated vector sum.
pub fn softmax_exp(mode: KernelMode, x: &mut [f32], max: f32) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if fma_active(mode) {
        return unsafe { x86::softmax_exp_fma(x, max) };
    }
    let _ = mode;
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    sum
}

/// Mode-dispatched numerically-stable softmax. The max scan stays
/// scalar in every tier (it is a trivial fraction of the work and
/// sidesteps the `f32::max` signed-zero subtlety); see [`softmax_exp`]
/// and [`scale`] for how the passes dispatch.
pub fn softmax(mode: KernelMode, x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let sum = softmax_exp(mode, x, max);
    scale(mode, x, 1.0 / sum);
}

// ------------------------------------------------------------- silu_mul

/// Fused SwiGLU gate: `gate[i] = silu(gate[i]) * up[i]`. `Reference`
/// and `Simd` share the scalar loop (libm `exp`, bit-identical);
/// `SimdFma` vectorizes with the polynomial `exp`.
pub fn silu_mul(mode: KernelMode, gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    #[cfg(target_arch = "x86_64")]
    if fma_active(mode) {
        return unsafe { x86::silu_mul_fma(gate, up) };
    }
    let _ = mode;
    for (g, &u) in gate.iter_mut().zip(up) {
        *g = ops::silu(*g) * u;
    }
}

// ===================================================== x86_64 backends

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 / AVX2+FMA kernels. Each non-FMA function replays the
    //! canonical scalar order of [`crate::tensor::ops`] exactly:
    //! per-lane multiply then add (`vmulps` + `vaddps`), the reference
    //! lane merge, an ordered scalar horizontal sum and the identical
    //! scalar tail — which is what makes `KernelMode::Simd` bit-exact.

    use core::arch::x86_64::*;

    /// Ordered horizontal sum of one 8-lane register: lane 0 + lane 1 +
    /// ... + lane 7, left to right, matching the scalar reference.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn hsum_ordered(v: __m256) -> f32 {
        let mut lane = [0.0f32; 8];
        _mm256_storeu_ps(lane.as_mut_ptr(), v);
        let mut s = lane[0];
        for &l in &lane[1..] {
            s += l;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / 16;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for i in 0..blocks {
            let x0 = _mm256_loadu_ps(pa.add(i * 16));
            let y0 = _mm256_loadu_ps(pb.add(i * 16));
            let x1 = _mm256_loadu_ps(pa.add(i * 16 + 8));
            let y1 = _mm256_loadu_ps(pb.add(i * 16 + 8));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(x0, y0));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(x1, y1));
        }
        let mut s = hsum_ordered(_mm256_add_ps(acc0, acc1));
        for i in blocks * 16..n {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / 16;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for i in 0..blocks {
            let x0 = _mm256_loadu_ps(pa.add(i * 16));
            let y0 = _mm256_loadu_ps(pb.add(i * 16));
            let x1 = _mm256_loadu_ps(pa.add(i * 16 + 8));
            let y1 = _mm256_loadu_ps(pb.add(i * 16 + 8));
            acc0 = _mm256_fmadd_ps(x0, y0, acc0);
            acc1 = _mm256_fmadd_ps(x1, y1, acc1);
        }
        let mut s = hsum_ordered(_mm256_add_ps(acc0, acc1));
        for i in blocks * 16..n {
            s += a[i] * b[i];
        }
        s
    }

    /// One A row accumulated into y over a 16-column block, mul+add.
    macro_rules! vecmat_body {
        ($x:ident, $a:ident, $m:ident, $y:ident, $madd:ident) => {{
            $y.fill(0.0);
            let n = $x.len();
            let pa = $a.as_ptr();
            let py = $y.as_mut_ptr();
            let mut i = 0;
            // row pairs: per output element the operation order is
            // row i then row i+1, exactly the scalar row-major order.
            while i + 2 <= n {
                let b0 = _mm256_set1_ps($x[i]);
                let b1 = _mm256_set1_ps($x[i + 1]);
                let r0 = pa.add(i * $m);
                let r1 = pa.add((i + 1) * $m);
                let mut j = 0;
                while j + 16 <= $m {
                    let mut y0 = _mm256_loadu_ps(py.add(j));
                    let mut y1 = _mm256_loadu_ps(py.add(j + 8));
                    y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), y0);
                    y1 = $madd(b0, _mm256_loadu_ps(r0.add(j + 8)), y1);
                    y0 = $madd(b1, _mm256_loadu_ps(r1.add(j)), y0);
                    y1 = $madd(b1, _mm256_loadu_ps(r1.add(j + 8)), y1);
                    _mm256_storeu_ps(py.add(j), y0);
                    _mm256_storeu_ps(py.add(j + 8), y1);
                    j += 16;
                }
                while j + 8 <= $m {
                    let mut y0 = _mm256_loadu_ps(py.add(j));
                    y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), y0);
                    y0 = $madd(b1, _mm256_loadu_ps(r1.add(j)), y0);
                    _mm256_storeu_ps(py.add(j), y0);
                    j += 8;
                }
                while j < $m {
                    let mut v = *py.add(j);
                    v += $x[i] * *r0.add(j);
                    v += $x[i + 1] * *r1.add(j);
                    *py.add(j) = v;
                    j += 1;
                }
                i += 2;
            }
            if i < n {
                let b0 = _mm256_set1_ps($x[i]);
                let r0 = pa.add(i * $m);
                let mut j = 0;
                while j + 8 <= $m {
                    let y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), _mm256_loadu_ps(py.add(j)));
                    _mm256_storeu_ps(py.add(j), y0);
                    j += 8;
                }
                while j < $m {
                    *py.add(j) += $x[i] * *r0.add(j);
                    j += 1;
                }
            }
        }};
    }

    /// Multiply-then-add (two rounded ops — bit-matches the scalar
    /// `y += x * a`).
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn madd_mul_add(a: __m256, b: __m256, c: __m256) -> __m256 {
        _mm256_add_ps(c, _mm256_mul_ps(a, b))
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vecmat_avx2(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_body!(x, a, m, y, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn vecmat_fma(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_body!(x, a, m, y, _mm256_fmadd_ps)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 16 <= n {
            let y0 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))),
            );
            let y1 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j + 8)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j + 8))),
            );
            _mm256_storeu_ps(py.add(j), y0);
            _mm256_storeu_ps(py.add(j + 8), y1);
            j += 16;
        }
        while j + 8 <= n {
            let y0 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))),
            );
            _mm256_storeu_ps(py.add(j), y0);
            j += 8;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_fma(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(px.add(j)), _mm256_loadu_ps(py.add(j)));
            _mm256_storeu_ps(py.add(j), y0);
            j += 8;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_avx2(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            _mm256_storeu_ps(px.add(j), _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))));
            j += 8;
        }
        while j < n {
            x[j] *= alpha;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn rms_apply_avx2(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
        let n = x.len();
        let vi = _mm256_set1_ps(inv);
        let (px, pg) = (x.as_ptr(), g.as_ptr());
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            // (x * inv) * g, same association as the scalar reference
            let v = _mm256_mul_ps(
                _mm256_mul_ps(_mm256_loadu_ps(px.add(j)), vi),
                _mm256_loadu_ps(pg.add(j)),
            );
            _mm256_storeu_ps(py.add(j), v);
            j += 8;
        }
        while j < n {
            y[j] = x[j] * inv * g[j];
            j += 1;
        }
    }

    /// Degree-6 polynomial `exp` for the fast-math tier: clamp,
    /// range-reduce by `n = round(x * log2(e))` with a two-part ln 2,
    /// Horner with FMA, then scale by `2^n` via exponent-bit arithmetic.
    /// Max observed error vs f64 libm is a few ULP (bounded in tests).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp256(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(88.376_26));
        let x = _mm256_max_ps(x, _mm256_set1_ps(-87.336_54));
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        // cvtps rounds to nearest-even (default MXCSR), giving n exactly.
        let e = _mm256_cvtps_epi32(_mm256_mul_ps(x, log2e));
        let n = _mm256_cvtepi32_ps(e);
        let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(0.693_359_4), x);
        let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(-2.121_944_4e-4), r);
        let mut p = _mm256_set1_ps(1.0 / 720.0);
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 120.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 24.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 6.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(0.5));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        let ebits = _mm256_add_epi32(e, _mm256_set1_epi32(127));
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(ebits));
        _mm256_mul_ps(p, pow2)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn softmax_exp_fma(x: &mut [f32], max: f32) -> f32 {
        let n = x.len();
        let vmax = _mm256_set1_ps(max);
        let px = x.as_mut_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let e = exp256(_mm256_sub_ps(_mm256_loadu_ps(px.add(j)), vmax));
            _mm256_storeu_ps(px.add(j), e);
            acc = _mm256_add_ps(acc, e);
            j += 8;
        }
        let mut sum = hsum_ordered(acc);
        while j < n {
            x[j] = (x[j] - max).exp();
            sum += x[j];
            j += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn silu_mul_fma(gate: &mut [f32], up: &[f32]) {
        let n = gate.len();
        let one = _mm256_set1_ps(1.0);
        let pg = gate.as_mut_ptr();
        let pu = up.as_ptr();
        let mut j = 0;
        while j + 8 <= n {
            let g = _mm256_loadu_ps(pg.add(j));
            let e = exp256(_mm256_sub_ps(_mm256_setzero_ps(), g));
            let s = _mm256_div_ps(g, _mm256_add_ps(one, e));
            _mm256_storeu_ps(pg.add(j), _mm256_mul_ps(s, _mm256_loadu_ps(pu.add(j))));
            j += 8;
        }
        while j < n {
            gate[j] = crate::tensor::ops::silu(gate[j]) * up[j];
            j += 1;
        }
    }

    // ------------------------------------------------- widening kernels

    /// Widen 8 packed bf16 values to 8 f32 lanes: zero-extend each u16
    /// to u32, shift into the high half, reinterpret. Exact by
    /// construction (bf16 is the top 16 bits of an f32).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen_bf16_8(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    /// Widen 8 packed f16 values via F16C `vcvtph2ps`. Exact: every
    /// IEEE half (normals, subnormals, infinities, NaNs) is
    /// representable in single precision, and the hardware conversion
    /// matches the software one bit for bit.
    #[inline]
    #[target_feature(enable = "avx2,f16c")]
    unsafe fn widen_f16_8(p: *const u16) -> __m256 {
        _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i))
    }

    /// [`dot_avx2`] with the b operand widened per 8-lane load; same
    /// canonical 16-block accumulators, lane merge and ordered sum.
    macro_rules! dot_wide_body {
        ($a:ident, $h:ident, $widen:ident, $w1:path, $madd:ident) => {{
            let n = $a.len();
            let blocks = n / 16;
            let pa = $a.as_ptr();
            let ph = $h.as_ptr();
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            for i in 0..blocks {
                let x0 = _mm256_loadu_ps(pa.add(i * 16));
                let x1 = _mm256_loadu_ps(pa.add(i * 16 + 8));
                acc0 = $madd(x0, $widen(ph.add(i * 16)), acc0);
                acc1 = $madd(x1, $widen(ph.add(i * 16 + 8)), acc1);
            }
            let mut s = hsum_ordered(_mm256_add_ps(acc0, acc1));
            for i in blocks * 16..n {
                s += $a[i] * $w1($h[i]);
            }
            s
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_wide_bf16_avx2(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_body!(a, h, widen_bf16_8, super::bf16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot_wide_bf16_fma(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_body!(a, h, widen_bf16_8, super::bf16_to_f32, _mm256_fmadd_ps)
    }

    #[target_feature(enable = "avx2,f16c")]
    pub(super) unsafe fn dot_wide_f16_avx2(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_body!(a, h, widen_f16_8, super::f16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    pub(super) unsafe fn dot_wide_f16_fma(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_body!(a, h, widen_f16_8, super::f16_to_f32, _mm256_fmadd_ps)
    }

    /// Elementwise `y += alpha * widen(h)`; any lane width bit-matches
    /// the scalar reference because each element is independent.
    macro_rules! axpy_wide_body {
        ($alpha:ident, $h:ident, $y:ident, $widen:ident, $w1:path, $madd:ident) => {{
            let n = $h.len();
            let va = _mm256_set1_ps($alpha);
            let ph = $h.as_ptr();
            let py = $y.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let y0 = $madd(va, $widen(ph.add(j)), _mm256_loadu_ps(py.add(j)));
                _mm256_storeu_ps(py.add(j), y0);
                j += 8;
            }
            while j < n {
                $y[j] += $alpha * $w1($h[j]);
                j += 1;
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_wide_bf16_avx2(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_body!(alpha, h, y, widen_bf16_8, super::bf16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_wide_bf16_fma(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_body!(alpha, h, y, widen_bf16_8, super::bf16_to_f32, _mm256_fmadd_ps)
    }

    #[target_feature(enable = "avx2,f16c")]
    pub(super) unsafe fn axpy_wide_f16_avx2(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_body!(alpha, h, y, widen_f16_8, super::f16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    pub(super) unsafe fn axpy_wide_f16_fma(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_body!(alpha, h, y, widen_f16_8, super::f16_to_f32, _mm256_fmadd_ps)
    }

    /// Row-major accumulate with widened rows. One row at a time: per
    /// output element the row order is the sequential scalar order, so
    /// this is bit-identical to [`super::vecmat_wide_scalar`].
    macro_rules! vecmat_wide_body {
        ($x:ident, $h:ident, $m:ident, $y:ident, $widen:ident, $w1:path, $madd:ident) => {{
            $y.fill(0.0);
            let ph = $h.as_ptr();
            let py = $y.as_mut_ptr();
            for (i, &xi) in $x.iter().enumerate() {
                let b0 = _mm256_set1_ps(xi);
                let row = ph.add(i * $m);
                let mut j = 0;
                while j + 8 <= $m {
                    let y0 = $madd(b0, $widen(row.add(j)), _mm256_loadu_ps(py.add(j)));
                    _mm256_storeu_ps(py.add(j), y0);
                    j += 8;
                }
                while j < $m {
                    *py.add(j) += xi * $w1(*row.add(j));
                    j += 1;
                }
            }
        }};
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vecmat_wide_bf16_avx2(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_body!(x, h, m, y, widen_bf16_8, super::bf16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn vecmat_wide_bf16_fma(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_body!(x, h, m, y, widen_bf16_8, super::bf16_to_f32, _mm256_fmadd_ps)
    }

    #[target_feature(enable = "avx2,f16c")]
    pub(super) unsafe fn vecmat_wide_f16_avx2(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_body!(x, h, m, y, widen_f16_8, super::f16_to_f32, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    pub(super) unsafe fn vecmat_wide_f16_fma(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_body!(x, h, m, y, widen_f16_8, super::f16_to_f32, _mm256_fmadd_ps)
    }
}

// ==================================================== aarch64 backends

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON kernels. The canonical 16-element block maps to four 4-lane
    //! registers: accumulators (a0, a1) cover scalar lanes 0..8 and
    //! (a2, a3) lanes 8..16, so the reference lane merge
    //! `lane[j] = acc[j] + acc[8 + j]` is `a0+a2` / `a1+a3` and the
    //! ordered horizontal sum walks the stored lanes left to right.

    use core::arch::aarch64::*;

    #[inline]
    unsafe fn hsum_ordered2(lo: float32x4_t, hi: float32x4_t) -> f32 {
        let mut lane = [0.0f32; 8];
        vst1q_f32(lane.as_mut_ptr(), lo);
        vst1q_f32(lane.as_mut_ptr().add(4), hi);
        let mut s = lane[0];
        for &l in &lane[1..] {
            s += l;
        }
        s
    }

    macro_rules! dot_neon_body {
        ($a:ident, $b:ident, $madd:ident) => {{
            let n = $a.len();
            let blocks = n / 16;
            let (pa, pb) = ($a.as_ptr(), $b.as_ptr());
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            for i in 0..blocks {
                let o = i * 16;
                a0 = $madd(a0, vld1q_f32(pa.add(o)), vld1q_f32(pb.add(o)));
                a1 = $madd(a1, vld1q_f32(pa.add(o + 4)), vld1q_f32(pb.add(o + 4)));
                a2 = $madd(a2, vld1q_f32(pa.add(o + 8)), vld1q_f32(pb.add(o + 8)));
                a3 = $madd(a3, vld1q_f32(pa.add(o + 12)), vld1q_f32(pb.add(o + 12)));
            }
            let mut s = hsum_ordered2(vaddq_f32(a0, a2), vaddq_f32(a1, a3));
            for i in blocks * 16..n {
                s += $a[i] * $b[i];
            }
            s
        }};
    }

    /// Multiply-then-add (two rounded ops, bit-matching the scalar ref).
    #[inline]
    unsafe fn madd_mul_add(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
        vaddq_f32(acc, vmulq_f32(x, y))
    }

    /// Fused multiply-add for the fast-math tier.
    #[inline]
    unsafe fn madd_fused(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
        vfmaq_f32(acc, x, y)
    }

    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body!(a, b, madd_mul_add)
    }

    pub(super) unsafe fn dot_fma_neon(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body!(a, b, madd_fused)
    }

    macro_rules! vecmat_neon_body {
        ($x:ident, $a:ident, $m:ident, $y:ident, $madd:ident) => {{
            $y.fill(0.0);
            let py = $y.as_mut_ptr();
            for (i, &xi) in $x.iter().enumerate() {
                let bx = vdupq_n_f32(xi);
                let row = $a.as_ptr().add(i * $m);
                let mut j = 0;
                while j + 4 <= $m {
                    let v = $madd(vld1q_f32(py.add(j)), bx, vld1q_f32(row.add(j)));
                    vst1q_f32(py.add(j), v);
                    j += 4;
                }
                while j < $m {
                    *py.add(j) += xi * *row.add(j);
                    j += 1;
                }
            }
        }};
    }

    pub(super) unsafe fn vecmat_neon(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_neon_body!(x, a, m, y, madd_mul_add)
    }

    pub(super) unsafe fn vecmat_fma_neon(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_neon_body!(x, a, m, y, madd_fused)
    }

    pub(super) unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vaddq_f32(vld1q_f32(py.add(j)), vmulq_f32(va, vld1q_f32(px.add(j))));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    pub(super) unsafe fn axpy_fma_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vfmaq_f32(vld1q_f32(py.add(j)), va, vld1q_f32(px.add(j)));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    pub(super) unsafe fn scale_neon(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            vst1q_f32(px.add(j), vmulq_f32(va, vld1q_f32(px.add(j))));
            j += 4;
        }
        while j < n {
            x[j] *= alpha;
            j += 1;
        }
    }

    pub(super) unsafe fn rms_apply_neon(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
        let n = x.len();
        let vi = vdupq_n_f32(inv);
        let (px, pg) = (x.as_ptr(), g.as_ptr());
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vmulq_f32(vmulq_f32(vld1q_f32(px.add(j)), vi), vld1q_f32(pg.add(j)));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] = x[j] * inv * g[j];
            j += 1;
        }
    }

    // ------------------------------------------------- widening kernels

    /// Widen 4 packed bf16 values to 4 f32 lanes: zero-extend the u16s
    /// to u32, shift into the high half, reinterpret. Exact by
    /// construction. (f16 has no exact NEON widen without the `fp16`
    /// extension, so the f16 path stays on the bit-identical scalar
    /// reference on aarch64.)
    #[inline]
    unsafe fn widen_bf16_4(p: *const u16) -> float32x4_t {
        vreinterpretq_f32_u32(vshlq_n_u32::<16>(vmovl_u16(vld1_u16(p))))
    }

    macro_rules! dot_wide_neon_body {
        ($a:ident, $h:ident, $madd:ident) => {{
            let n = $a.len();
            let blocks = n / 16;
            let pa = $a.as_ptr();
            let ph = $h.as_ptr();
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            for i in 0..blocks {
                let o = i * 16;
                a0 = $madd(a0, vld1q_f32(pa.add(o)), widen_bf16_4(ph.add(o)));
                a1 = $madd(a1, vld1q_f32(pa.add(o + 4)), widen_bf16_4(ph.add(o + 4)));
                a2 = $madd(a2, vld1q_f32(pa.add(o + 8)), widen_bf16_4(ph.add(o + 8)));
                a3 = $madd(a3, vld1q_f32(pa.add(o + 12)), widen_bf16_4(ph.add(o + 12)));
            }
            let mut s = hsum_ordered2(vaddq_f32(a0, a2), vaddq_f32(a1, a3));
            for i in blocks * 16..n {
                s += $a[i] * super::bf16_to_f32($h[i]);
            }
            s
        }};
    }

    pub(super) unsafe fn dot_wide_bf16_neon(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_neon_body!(a, h, madd_mul_add)
    }

    pub(super) unsafe fn dot_wide_bf16_fma_neon(a: &[f32], h: &[u16]) -> f32 {
        dot_wide_neon_body!(a, h, madd_fused)
    }

    macro_rules! axpy_wide_neon_body {
        ($alpha:ident, $h:ident, $y:ident, $madd:ident) => {{
            let n = $h.len();
            let va = vdupq_n_f32($alpha);
            let ph = $h.as_ptr();
            let py = $y.as_mut_ptr();
            let mut j = 0;
            while j + 4 <= n {
                let v = $madd(vld1q_f32(py.add(j)), va, widen_bf16_4(ph.add(j)));
                vst1q_f32(py.add(j), v);
                j += 4;
            }
            while j < n {
                $y[j] += $alpha * super::bf16_to_f32($h[j]);
                j += 1;
            }
        }};
    }

    pub(super) unsafe fn axpy_wide_bf16_neon(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_neon_body!(alpha, h, y, madd_mul_add)
    }

    pub(super) unsafe fn axpy_wide_bf16_fma_neon(alpha: f32, h: &[u16], y: &mut [f32]) {
        axpy_wide_neon_body!(alpha, h, y, madd_fused)
    }

    macro_rules! vecmat_wide_neon_body {
        ($x:ident, $h:ident, $m:ident, $y:ident, $madd:ident) => {{
            $y.fill(0.0);
            let ph = $h.as_ptr();
            let py = $y.as_mut_ptr();
            for (i, &xi) in $x.iter().enumerate() {
                let bx = vdupq_n_f32(xi);
                let row = ph.add(i * $m);
                let mut j = 0;
                while j + 4 <= $m {
                    let v = $madd(vld1q_f32(py.add(j)), bx, widen_bf16_4(row.add(j)));
                    vst1q_f32(py.add(j), v);
                    j += 4;
                }
                while j < $m {
                    *py.add(j) += xi * super::bf16_to_f32(*row.add(j));
                    j += 1;
                }
            }
        }};
    }

    pub(super) unsafe fn vecmat_wide_bf16_neon(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_neon_body!(x, h, m, y, madd_mul_add)
    }

    pub(super) unsafe fn vecmat_wide_bf16_fma_neon(x: &[f32], h: &[u16], m: usize, y: &mut [f32]) {
        vecmat_wide_neon_body!(x, h, m, y, madd_fused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn f64_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in KernelMode::all() {
            assert_eq!(KernelMode::parse(m.name()), Some(m));
        }
        assert_eq!(KernelMode::parse("ref"), Some(KernelMode::Reference));
        assert_eq!(KernelMode::parse("fma"), Some(KernelMode::SimdFma));
        assert_eq!(KernelMode::parse("nope"), None);
        assert_eq!(KernelMode::default(), KernelMode::Simd);
    }

    #[test]
    fn backend_name_is_stable() {
        let n = backend_name();
        assert!(["scalar", "avx2", "avx2+fma", "neon"].contains(&n), "{n}");
        assert_eq!(n, backend_name());
    }

    /// The tentpole invariant: `Simd` output is bitwise equal to the
    /// scalar reference for every primitive, across lane-remainder
    /// lengths (tails), unaligned starts, and random data.
    #[test]
    fn simd_bit_identical_to_reference() {
        check(40, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let m = 1 + rng.below(70);
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            prop_assert(
                dot(KernelMode::Simd, &a, &b).to_bits() == ops::dot(&a, &b).to_bits(),
                "dot bits",
            )?;

            let w = rng.normal_vec(n * m);
            let mut y_ref = vec![0.0f32; m];
            let mut y_simd = vec![0.0f32; m];
            ops::vecmat(&a, &w, m, &mut y_ref);
            vecmat(KernelMode::Simd, &a, &w, m, &mut y_simd);
            prop_assert(bits(&y_ref) == bits(&y_simd), "vecmat bits")?;

            let alpha = rng.normal();
            let mut y2_ref = y_ref.clone();
            let mut y2_simd = y_ref.clone();
            axpy_scalar(alpha, &b[..m.min(n)], &mut y2_ref[..m.min(n)]);
            axpy(KernelMode::Simd, alpha, &b[..m.min(n)], &mut y2_simd[..m.min(n)]);
            prop_assert(bits(&y2_ref) == bits(&y2_simd), "axpy bits")?;

            let g = rng.normal_vec(n);
            let mut r_ref = vec![0.0f32; n];
            let mut r_simd = vec![0.0f32; n];
            ops::rms_norm(&a, &g, &mut r_ref, 1e-5);
            rms_norm(KernelMode::Simd, &a, &g, &mut r_simd, 1e-5);
            prop_assert(bits(&r_ref) == bits(&r_simd), "rms_norm bits")?;

            let mut s_ref = a.clone();
            let mut s_simd = a.clone();
            ops::softmax(&mut s_ref);
            softmax(KernelMode::Simd, &mut s_simd);
            prop_assert(bits(&s_ref) == bits(&s_simd), "softmax bits")?;

            let mut g_ref = a.clone();
            let mut g_simd = a.clone();
            let up = rng.normal_vec(n);
            silu_mul(KernelMode::Reference, &mut g_ref, &up);
            silu_mul(KernelMode::Simd, &mut g_simd, &up);
            prop_assert(bits(&g_ref) == bits(&g_simd), "silu_mul bits")
        });
    }

    #[test]
    fn matmul_modes_match_reference() {
        let mut rng = Rng::new(9);
        let (n, k, m) = (5, 33, 17);
        let a = rng.normal_vec(n * k);
        let b = rng.normal_vec(k * m);
        let mut c_ref = vec![0.0f32; n * m];
        let mut c_simd = vec![0.0f32; n * m];
        ops::matmul(&a, &b, n, k, m, &mut c_ref);
        matmul(KernelMode::Simd, &a, &b, n, k, m, &mut c_simd);
        assert_eq!(bits(&c_ref), bits(&c_simd));
        let mut c_fma = vec![0.0f32; n * m];
        matmul(KernelMode::SimdFma, &a, &b, n, k, m, &mut c_fma);
        for (x, y) in c_ref.iter().zip(&c_fma) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0));
        }
    }

    fn bits(x: &[f32]) -> Vec<u32> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    /// ULP distance between an f32 and an f64 reference value.
    fn ulp_err(got: f32, want: f64) -> f64 {
        let w = want as f32;
        let ulp = (w.abs().max(f32::MIN_POSITIVE) * f32::EPSILON) as f64;
        ((got as f64) - want).abs() / ulp
    }

    /// SimdFma forward-error bounds vs f64 accumulation: FMA reductions
    /// must stay within C·eps·sum(|terms|) of the f64 result (the
    /// standard sequential-summation bound with headroom; the canonical
    /// blocked order keeps the constant small).
    #[test]
    fn fma_dot_ulp_bounded_vs_f64() {
        check(40, |rng: &mut Rng| {
            let n = 1 + rng.below(600);
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let want = f64_dot(&a, &b);
            let got = dot(KernelMode::SimdFma, &a, &b) as f64;
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let bound = (f32::EPSILON as f64) * mag * (8.0 + (n as f64) / 2.0);
            prop_assert((got - want).abs() <= bound, "fma dot exceeds forward-error bound")
        });
    }

    #[test]
    fn fma_vecmat_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(120);
            let m = 1 + rng.below(50);
            let x = rng.normal_vec(n);
            let w = rng.normal_vec(n * m);
            let mut y = vec![0.0f32; m];
            vecmat(KernelMode::SimdFma, &x, &w, m, &mut y);
            for j in 0..m {
                let want: f64 = (0..n).map(|i| x[i] as f64 * w[i * m + j] as f64).sum();
                let mag: f64 = (0..n).map(|i| (x[i] as f64 * w[i * m + j] as f64).abs()).sum();
                let bound = (f32::EPSILON as f64) * mag * (8.0 + (n as f64) / 2.0);
                prop_assert((y[j] as f64 - want).abs() <= bound, "fma vecmat bound")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fma_rms_norm_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let x = rng.normal_vec(n);
            let g = rng.normal_vec(n);
            let mut y = vec![0.0f32; n];
            rms_norm(KernelMode::SimdFma, &x, &g, &mut y, 1e-5);
            let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64;
            let inv = 1.0 / (ms + 1e-5f64).sqrt();
            for i in 0..n {
                let want = x[i] as f64 * inv * g[i] as f64;
                prop_assert(ulp_err(y[i], want) <= 16.0 + n as f64 / 4.0, "fma rms_norm ulp")?;
            }
            Ok(())
        });
    }

    /// The polynomial exp inside SimdFma softmax must stay within a few
    /// ULP of libm, and the resulting distribution within tight ULPs of
    /// the f64 softmax.
    #[test]
    fn fma_softmax_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let x = rng.normal_vec(n);
            let mut got = x.clone();
            softmax(KernelMode::SimdFma, &mut got);
            let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let exps: Vec<f64> = x.iter().map(|&v| ((v as f64) - max).exp()).collect();
            let denom: f64 = exps.iter().sum();
            let s: f32 = got.iter().sum();
            prop_assert((s as f64 - 1.0).abs() < 1e-5, "fma softmax sums to one")?;
            for (i, &e) in exps.iter().enumerate() {
                let want = e / denom;
                // poly-exp (few ULP) + reassociated sum (n/8 chain)
                prop_assert(ulp_err(got[i], want) <= 32.0 + n as f64 / 4.0, "fma softmax ulp")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fma_silu_mul_close_to_reference() {
        let mut rng = Rng::new(11);
        let n = 333;
        let g0 = rng.normal_vec(n);
        let up = rng.normal_vec(n);
        let mut g_ref = g0.clone();
        silu_mul(KernelMode::Reference, &mut g_ref, &up);
        let mut g_fma = g0.clone();
        silu_mul(KernelMode::SimdFma, &mut g_fma, &up);
        for i in 0..n {
            let want = (g0[i] as f64) / (1.0 + (-(g0[i] as f64)).exp()) * up[i] as f64;
            assert!(ulp_err(g_fma[i], want) <= 32.0, "silu ulp at {i}");
            assert!((g_ref[i] - g_fma[i]).abs() <= 1e-5 * g_ref[i].abs().max(1.0));
        }
    }

    /// exp edge cases through the softmax path: large negative inputs
    /// must underflow toward zero without producing NaN/inf, and the
    /// clamp must keep large positives finite.
    #[test]
    fn fma_softmax_extreme_logits_stay_finite() {
        let mut x = vec![1000.0f32, 1001.0, 999.0, -1000.0, 0.0, -87.0, 12.0, -3.0, 5.5];
        softmax(KernelMode::SimdFma, &mut x);
        assert!(x.iter().all(|v| v.is_finite() && *v >= 0.0));
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    // ------------------------------------------------- KvDtype + wide

    #[test]
    fn kv_dtype_parse_roundtrip() {
        for d in KvDtype::all() {
            assert_eq!(KvDtype::parse(d.name()), Some(d));
        }
        assert_eq!(KvDtype::parse("fp16"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("bfloat16"), Some(KvDtype::Bf16));
        assert_eq!(KvDtype::parse("half"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("double"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.elems(6), 6);
        assert_eq!(KvDtype::Bf16.elems(6), 3);
        assert_eq!(KvDtype::F16.bytes(), 2);
    }

    /// Exhaustive over all 2^16 half patterns: widening is exact and
    /// re-quantizing the widened value returns the identical bits (the
    /// losslessness both the packed round-trip tests and the CoW fork
    /// property in halfkv.rs rely on). NaN payloads may canonicalize,
    /// so NaN checks only that NaN-ness survives.
    #[test]
    fn half_widen_then_requantize_is_identity() {
        for bits16 in 0..=u16::MAX {
            let wb = bf16_to_f32(bits16);
            if wb.is_nan() {
                assert!(f32::from_bits((bits16 as u32) << 16).is_nan());
            } else {
                assert_eq!(f32_to_bf16(wb), bits16, "bf16 {bits16:#06x}");
            }
            let wf = f16_to_f32(bits16);
            if wf.is_nan() {
                let q = f32_to_f16(wf);
                assert!((q & 0x7C00) == 0x7C00 && (q & 0x03FF) != 0);
            } else {
                assert_eq!(f32_to_f16(wf), bits16, "f16 {bits16:#06x}");
            }
        }
    }

    /// Quantization rounds to nearest: the chosen half value is at
    /// least as close to the input as both of its neighbours.
    #[test]
    fn half_quantize_rounds_to_nearest() {
        check(60, |rng: &mut Rng| {
            let x = rng.normal() * 10.0f32.powi(rng.below(7) as i32 - 3);
            for d in [KvDtype::Bf16, KvDtype::F16] {
                let q = match d {
                    KvDtype::Bf16 => f32_to_bf16(x),
                    _ => f32_to_f16(x),
                };
                let got = widen1(d, q);
                let err = (got as f64 - x as f64).abs();
                for delta in [-1i32, 1] {
                    let nb = (q as i32 + delta) as u16;
                    let nv = widen1(d, nb);
                    if nv.is_finite() && nv.is_sign_positive() == got.is_sign_positive() {
                        let nerr = (nv as f64 - x as f64).abs();
                        prop_assert(err <= nerr, "not nearest")?;
                    }
                }
            }
            Ok(())
        });
    }

    /// Relative quantization error bounds for normal-range values: bf16
    /// keeps 8 significand bits (rel err <= 2^-9 + slack), f16 keeps 11
    /// (rel err <= 2^-12 + slack). These are the bounds PERFORMANCE.md
    /// documents and halfkv.rs budgets its logit tolerances from.
    #[test]
    fn half_quantization_relative_error_bounded() {
        check(60, |rng: &mut Rng| {
            let x = rng.normal();
            if x.abs() < 1e-3 {
                return Ok(());
            }
            let x64 = x as f64;
            let eb = (widen1(KvDtype::Bf16, f32_to_bf16(x)) as f64 - x64).abs() / x64.abs();
            prop_assert(eb <= 1.0 / 256.0, "bf16 rel err")?;
            let ef = (widen1(KvDtype::F16, f32_to_f16(x)) as f64 - x64).abs() / x64.abs();
            prop_assert(ef <= 1.0 / 2048.0, "f16 rel err")
        });
    }

    #[test]
    fn f16_edge_cases() {
        assert_eq!(f16_to_f32(f32_to_f16(0.0)).to_bits(), 0.0f32.to_bits());
        assert_eq!(f16_to_f32(f32_to_f16(-0.0)).to_bits(), (-0.0f32).to_bits());
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // overflow saturates to infinity, tiny values flush to signed zero
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e-10)).to_bits(), (-0.0f32).to_bits());
        // largest normal and a subnormal survive the round trip
        assert_eq!(f16_to_f32(0x7BFF), 65504.0);
        assert_eq!(f16_to_f32(0x0001), 5.960_464_5e-8);
        // NaN poison survives packing an f32 NaN into either half slot
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    /// pack_row / widen_row round-trip: packing a row of values already
    /// representable in the target dtype and widening it back is
    /// bitwise lossless, and pack_extend matches pack_row.
    #[test]
    fn pack_widen_round_trip_lossless() {
        check(40, |rng: &mut Rng| {
            let dh = 2 * (1 + rng.below(40));
            for d in [KvDtype::Bf16, KvDtype::F16] {
                // snap to representable values first
                let row: Vec<f32> = (0..dh)
                    .map(|_| {
                        widen1(
                            d,
                            match d {
                                KvDtype::Bf16 => f32_to_bf16(rng.normal()),
                                _ => f32_to_f16(rng.normal()),
                            },
                        )
                    })
                    .collect();
                let mut packed = vec![0.0f32; d.elems(dh)];
                pack_row(d, &row, &mut packed);
                let mut back = vec![0.0f32; dh];
                widen_row(d, &packed, &mut back);
                prop_assert(bits(&row) == bits(&back), "pack/widen round trip")?;

                let mut ext = Vec::new();
                pack_extend(d, &row, &mut ext);
                prop_assert(bits(&ext) == bits(&packed), "pack_extend == pack_row")?;
                let mut wide = Vec::new();
                widen_extend(d, &ext, &mut wide);
                prop_assert(bits(&wide) == bits(&row), "widen_extend round trip")?;
            }
            Ok(())
        });
    }

    /// Wide-kernel tentpole invariant: `Simd` is bitwise equal to the
    /// scalar reference for every dtype, across tail lengths and random
    /// data — same contract as the f32 kernels.
    #[test]
    fn wide_simd_bit_identical_to_reference() {
        check(40, |rng: &mut Rng| {
            // half rows need even lengths; n % 16 still sweeps the tails
            let n = 2 * (1 + rng.below(100));
            let m = 2 * (1 + rng.below(35));
            let a = rng.normal_vec(n);
            for d in KvDtype::all() {
                let kv = rng.normal_vec(n);
                let mut packed = vec![0.0f32; d.elems(n)];
                if d == KvDtype::F32 {
                    packed.copy_from_slice(&kv);
                } else {
                    pack_row(d, &kv, &mut packed);
                }
                let r = dot_wide(KernelMode::Reference, d, &a, &packed);
                let s = dot_wide(KernelMode::Simd, d, &a, &packed);
                prop_assert(r.to_bits() == s.to_bits(), "dot_wide bits")?;

                let alpha = rng.normal();
                let mut y_ref = rng.normal_vec(n);
                let mut y_simd = y_ref.clone();
                axpy_wide(KernelMode::Reference, d, alpha, &packed, &mut y_ref);
                axpy_wide(KernelMode::Simd, d, alpha, &packed, &mut y_simd);
                prop_assert(bits(&y_ref) == bits(&y_simd), "axpy_wide bits")?;

                let w = rng.normal_vec(n * m);
                let mut wp = vec![0.0f32; d.elems(n * m)];
                if d == KvDtype::F32 {
                    wp.copy_from_slice(&w);
                } else {
                    pack_row(d, &w, &mut wp);
                }
                let mut v_ref = vec![0.0f32; m];
                let mut v_simd = vec![0.0f32; m];
                vecmat_wide(KernelMode::Reference, d, &a, &wp, m, &mut v_ref);
                vecmat_wide(KernelMode::Simd, d, &a, &wp, m, &mut v_simd);
                prop_assert(bits(&v_ref) == bits(&v_simd), "vecmat_wide bits")?;
            }
            Ok(())
        });
    }

    /// F32 delegation: `dot_wide`/`axpy_wide`/`vecmat_wide` over
    /// `KvDtype::F32` are exactly the f32 kernels.
    #[test]
    fn wide_f32_delegates_to_f32_kernels() {
        let mut rng = Rng::new(13);
        let (n, m) = (77, 18);
        let a = rng.normal_vec(n);
        let b = rng.normal_vec(n);
        for mode in KernelMode::all() {
            assert_eq!(
                dot_wide(mode, KvDtype::F32, &a, &b).to_bits(),
                dot(mode, &a, &b).to_bits()
            );
        }
        let w = rng.normal_vec(n * m);
        let mut y1 = vec![0.0f32; m];
        let mut y2 = vec![0.0f32; m];
        vecmat_wide(KernelMode::Simd, KvDtype::F32, &a, &w, m, &mut y1);
        vecmat(KernelMode::Simd, &a, &w, m, &mut y2);
        assert_eq!(bits(&y1), bits(&y2));
    }

    /// SimdFma wide reductions stay within the same forward-error bound
    /// as the f32 FMA dot, measured against f64 accumulation of the
    /// *widened* values (quantization error is excluded by design —
    /// it's bounded separately above).
    #[test]
    fn fma_wide_dot_bounded_vs_f64() {
        check(30, |rng: &mut Rng| {
            let n = 2 * (1 + rng.below(300));
            let a = rng.normal_vec(n);
            let kv = rng.normal_vec(n);
            for d in [KvDtype::Bf16, KvDtype::F16] {
                let mut packed = vec![0.0f32; d.elems(n)];
                pack_row(d, &kv, &mut packed);
                let mut wide = vec![0.0f32; n];
                widen_row(d, &packed, &mut wide);
                let want = f64_dot(&a, &wide);
                let got = dot_wide(KernelMode::SimdFma, d, &a, &packed) as f64;
                let mag: f64 =
                    a.iter().zip(&wide).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
                let bound = (f32::EPSILON as f64) * mag * (8.0 + (n as f64) / 2.0);
                prop_assert((got - want).abs() <= bound, "fma wide dot bound")?;
            }
            Ok(())
        });
    }
}
