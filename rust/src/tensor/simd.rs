//! Runtime-dispatched SIMD f32 kernels (`--kernels`, ROADMAP item 3).
//!
//! Every primitive here comes in three tiers selected by [`KernelMode`]:
//!
//! * `Reference` — the scalar loops in [`crate::tensor::ops`], which fix
//!   the canonical accumulation order (16-element blocks, two 8-lane
//!   accumulator groups, ordered horizontal sum).
//! * `Simd` (default) — explicit 8-lane AVX2 (x86_64) or 4-lane NEON
//!   (aarch64) kernels that replay the *same* per-element operation
//!   sequence: lane-parallel multiply-then-add with the reference's
//!   lane merge and ordered horizontal reduction, never a fused
//!   multiply-add and never a reassociated sum. Output is bit-identical
//!   to `Reference` on every input (asserted across the whole engine
//!   matrix in `rust/tests/parallel.rs`).
//! * `SimdFma` — the documented fast-math tier: fused multiply-add
//!   contractions and a vectorized polynomial `exp`. Results differ
//!   from the reference by bounded ULPs (FMA keeps the intermediate
//!   product in full precision, so reductions are *more* accurate, and
//!   the degree-6 `exp` polynomial is within a few ULP of libm); the
//!   equivalence tests below bound the error against f64 accumulation.
//!
//! Dispatch is resolved once per process from CPU features
//! (`is_x86_feature_detected!`) and cached; `HATA_SIMD=scalar` in the
//! environment forces the scalar fallback so both dispatch paths stay
//! testable on any host (the CI matrix runs one leg this way). When no
//! vector backend is available, `Simd` and `SimdFma` silently fall back
//! to the reference loops — `Simd` is bit-identical anyway, and the
//! fallback keeps aarch64-without-NEON and other targets correct.

use crate::tensor::ops;

/// Which f32 kernel implementation tier the engine uses (`--kernels`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// Scalar canonical-order reference loops ([`crate::tensor::ops`]).
    Reference,
    /// Explicit-lane SIMD, bit-identical to `Reference` (the default).
    #[default]
    Simd,
    /// SIMD with fused multiply-add and polynomial `exp`: fast-math
    /// tier, ULP-bounded (not bitwise) equivalence to `Reference`.
    SimdFma,
}

impl KernelMode {
    /// Parse a CLI value (`reference` | `simd` | `simd-fma`).
    pub fn parse(s: &str) -> Option<KernelMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "reference" | "ref" | "scalar" => KernelMode::Reference,
            "simd" => KernelMode::Simd,
            "simd-fma" | "simdfma" | "fma" => KernelMode::SimdFma,
            _ => return None,
        })
    }

    /// Canonical lowercase name (CLI value, bench row label).
    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Reference => "reference",
            KernelMode::Simd => "simd",
            KernelMode::SimdFma => "simd-fma",
        }
    }

    /// All modes, for bench/test sweeps.
    pub fn all() -> [KernelMode; 3] {
        [KernelMode::Reference, KernelMode::Simd, KernelMode::SimdFma]
    }
}

/// Vector backend resolved at runtime (one cached probe per process).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2 { fma: bool },
    #[cfg(target_arch = "aarch64")]
    Neon,
}

fn detect_backend() -> Backend {
    if let Ok(v) = std::env::var("HATA_SIMD") {
        let v = v.to_ascii_lowercase();
        if v == "scalar" || v == "off" || v == "0" {
            return Backend::Scalar;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Backend::Avx2 { fma: std::arch::is_x86_feature_detected!("fma") };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Backend::Neon;
    }
    #[allow(unreachable_code)]
    Backend::Scalar
}

fn backend() -> Backend {
    static CACHE: std::sync::OnceLock<Backend> = std::sync::OnceLock::new();
    *CACHE.get_or_init(detect_backend)
}

/// Human-readable name of the active vector backend (bench headers,
/// `--verbose` logs): `"avx2+fma"`, `"avx2"`, `"neon"` or `"scalar"`.
pub fn backend_name() -> &'static str {
    match backend() {
        Backend::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 { fma: true } => "avx2+fma",
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 { fma: false } => "avx2",
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => "neon",
    }
}

/// True when `mode` will actually run the fused-multiply-add polynomial
/// kernels on this host (SimdFma requested and AVX2+FMA detected).
#[cfg(target_arch = "x86_64")]
fn fma_active(mode: KernelMode) -> bool {
    mode == KernelMode::SimdFma && matches!(backend(), Backend::Avx2 { fma: true })
}

// ------------------------------------------------------------------ dot

/// Mode-dispatched dot product. `Reference`/`Simd` are bit-identical
/// (canonical [`ops::dot`] order); `SimdFma` contracts with FMA.
#[inline]
pub fn dot(mode: KernelMode, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match mode {
        KernelMode::Reference => ops::dot(a, b),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::dot_avx2(a, b) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::dot_neon(a, b) },
            _ => ops::dot(a, b),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true } => unsafe { x86::dot_fma(a, b) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false } => unsafe { x86::dot_avx2(a, b) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::dot_fma_neon(a, b) },
            _ => ops::dot(a, b),
        },
    }
}

// --------------------------------------------------------------- vecmat

/// Mode-dispatched vector–matrix product `y[j] = sum_i x[i] * a[i, j]`
/// (the decode projection shape). Lane-parallel per output element, so
/// `Simd` is bit-identical to [`ops::vecmat`] at any lane width.
pub fn vecmat(mode: KernelMode, x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), x.len() * m);
    debug_assert_eq!(y.len(), m);
    match mode {
        KernelMode::Reference => ops::vecmat(x, a, m, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::vecmat_avx2(x, a, m, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::vecmat_neon(x, a, m, y) },
            _ => ops::vecmat(x, a, m, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true } => unsafe { x86::vecmat_fma(x, a, m, y) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false } => unsafe { x86::vecmat_avx2(x, a, m, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::vecmat_fma_neon(x, a, m, y) },
            _ => ops::vecmat(x, a, m, y),
        },
    }
}

/// Mode-dispatched matmul: one [`vecmat`] per output row (the reference
/// ikj order), C = A @ B for row-major A [n, k], B [k, m] -> C [n, m].
pub fn matmul(mode: KernelMode, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    debug_assert_eq!(c.len(), n * m);
    for i in 0..n {
        vecmat(mode, &a[i * k..(i + 1) * k], b, m, &mut c[i * m..(i + 1) * m]);
    }
}

// ----------------------------------------------------------------- axpy

/// y += alpha * x (the attention `o += p * v` row update). One
/// independent multiply-then-add per element, so every lane width is
/// bit-identical; `SimdFma` contracts to `fmadd`.
#[inline]
pub fn axpy(mode: KernelMode, alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    match mode {
        KernelMode::Reference => axpy_scalar(alpha, x, y),
        KernelMode::Simd => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::axpy_avx2(alpha, x, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::axpy_neon(alpha, x, y) },
            _ => axpy_scalar(alpha, x, y),
        },
        KernelMode::SimdFma => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: true } => unsafe { x86::axpy_fma(alpha, x, y) },
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { fma: false } => unsafe { x86::axpy_avx2(alpha, x, y) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::axpy_fma_neon(alpha, x, y) },
            _ => axpy_scalar(alpha, x, y),
        },
    }
}

fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yj, &xj) in y.iter_mut().zip(x) {
        *yj += alpha * xj;
    }
}

// ---------------------------------------------------------------- scale

/// x *= alpha in place (softmax normalization pass). Lane-parallel,
/// bit-identical at any width.
#[inline]
pub fn scale(mode: KernelMode, x: &mut [f32], alpha: f32) {
    match mode {
        KernelMode::Reference => scale_scalar(x, alpha),
        _ => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::scale_avx2(x, alpha) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::scale_neon(x, alpha) },
            _ => scale_scalar(x, alpha),
        },
    }
}

fn scale_scalar(x: &mut [f32], alpha: f32) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

// ------------------------------------------------------------- rms_norm

/// Mode-dispatched RMSNorm `y = x / rms(x) * g`. The mean square is the
/// canonical [`dot`]`(x, x)` reduction; the normalization pass computes
/// `(x[i] * inv) * g[i]` per element in every tier.
pub fn rms_norm(mode: KernelMode, x: &[f32], g: &[f32], y: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let ms = dot(mode, x, x) / n;
    let inv = 1.0 / (ms + eps).sqrt();
    match mode {
        KernelMode::Reference => rms_apply_scalar(x, g, y, inv),
        _ => match backend() {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 { .. } => unsafe { x86::rms_apply_avx2(x, g, y, inv) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { neon::rms_apply_neon(x, g, y, inv) },
            _ => rms_apply_scalar(x, g, y, inv),
        },
    }
}

fn rms_apply_scalar(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
    for ((yi, &xi), &gi) in y.iter_mut().zip(x).zip(g) {
        *yi = xi * inv * gi;
    }
}

// ------------------------------------------------------------- softmax

/// Streaming-softmax exponential pass: `x[t] = exp(x[t] - max)` in
/// place, returning the sum of the exponentials (the denominator).
/// `Reference` and `Simd` run the identical sequential scalar loop —
/// `exp` stays libm and the sum order is fixed, preserving bit
/// equality — while `SimdFma` batches a degree-6 polynomial `exp`
/// across lanes with a reassociated vector sum.
pub fn softmax_exp(mode: KernelMode, x: &mut [f32], max: f32) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if fma_active(mode) {
        return unsafe { x86::softmax_exp_fma(x, max) };
    }
    let _ = mode;
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    sum
}

/// Mode-dispatched numerically-stable softmax. The max scan stays
/// scalar in every tier (it is a trivial fraction of the work and
/// sidesteps the `f32::max` signed-zero subtlety); see [`softmax_exp`]
/// and [`scale`] for how the passes dispatch.
pub fn softmax(mode: KernelMode, x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let sum = softmax_exp(mode, x, max);
    scale(mode, x, 1.0 / sum);
}

// ------------------------------------------------------------- silu_mul

/// Fused SwiGLU gate: `gate[i] = silu(gate[i]) * up[i]`. `Reference`
/// and `Simd` share the scalar loop (libm `exp`, bit-identical);
/// `SimdFma` vectorizes with the polynomial `exp`.
pub fn silu_mul(mode: KernelMode, gate: &mut [f32], up: &[f32]) {
    debug_assert_eq!(gate.len(), up.len());
    #[cfg(target_arch = "x86_64")]
    if fma_active(mode) {
        return unsafe { x86::silu_mul_fma(gate, up) };
    }
    let _ = mode;
    for (g, &u) in gate.iter_mut().zip(up) {
        *g = ops::silu(*g) * u;
    }
}

// ===================================================== x86_64 backends

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 / AVX2+FMA kernels. Each non-FMA function replays the
    //! canonical scalar order of [`crate::tensor::ops`] exactly:
    //! per-lane multiply then add (`vmulps` + `vaddps`), the reference
    //! lane merge, an ordered scalar horizontal sum and the identical
    //! scalar tail — which is what makes `KernelMode::Simd` bit-exact.

    use core::arch::x86_64::*;

    /// Ordered horizontal sum of one 8-lane register: lane 0 + lane 1 +
    /// ... + lane 7, left to right, matching the scalar reference.
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn hsum_ordered(v: __m256) -> f32 {
        let mut lane = [0.0f32; 8];
        _mm256_storeu_ps(lane.as_mut_ptr(), v);
        let mut s = lane[0];
        for &l in &lane[1..] {
            s += l;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / 16;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for i in 0..blocks {
            let x0 = _mm256_loadu_ps(pa.add(i * 16));
            let y0 = _mm256_loadu_ps(pb.add(i * 16));
            let x1 = _mm256_loadu_ps(pa.add(i * 16 + 8));
            let y1 = _mm256_loadu_ps(pb.add(i * 16 + 8));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(x0, y0));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(x1, y1));
        }
        let mut s = hsum_ordered(_mm256_add_ps(acc0, acc1));
        for i in blocks * 16..n {
            s += a[i] * b[i];
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot_fma(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n / 16;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for i in 0..blocks {
            let x0 = _mm256_loadu_ps(pa.add(i * 16));
            let y0 = _mm256_loadu_ps(pb.add(i * 16));
            let x1 = _mm256_loadu_ps(pa.add(i * 16 + 8));
            let y1 = _mm256_loadu_ps(pb.add(i * 16 + 8));
            acc0 = _mm256_fmadd_ps(x0, y0, acc0);
            acc1 = _mm256_fmadd_ps(x1, y1, acc1);
        }
        let mut s = hsum_ordered(_mm256_add_ps(acc0, acc1));
        for i in blocks * 16..n {
            s += a[i] * b[i];
        }
        s
    }

    /// One A row accumulated into y over a 16-column block, mul+add.
    macro_rules! vecmat_body {
        ($x:ident, $a:ident, $m:ident, $y:ident, $madd:ident) => {{
            $y.fill(0.0);
            let n = $x.len();
            let pa = $a.as_ptr();
            let py = $y.as_mut_ptr();
            let mut i = 0;
            // row pairs: per output element the operation order is
            // row i then row i+1, exactly the scalar row-major order.
            while i + 2 <= n {
                let b0 = _mm256_set1_ps($x[i]);
                let b1 = _mm256_set1_ps($x[i + 1]);
                let r0 = pa.add(i * $m);
                let r1 = pa.add((i + 1) * $m);
                let mut j = 0;
                while j + 16 <= $m {
                    let mut y0 = _mm256_loadu_ps(py.add(j));
                    let mut y1 = _mm256_loadu_ps(py.add(j + 8));
                    y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), y0);
                    y1 = $madd(b0, _mm256_loadu_ps(r0.add(j + 8)), y1);
                    y0 = $madd(b1, _mm256_loadu_ps(r1.add(j)), y0);
                    y1 = $madd(b1, _mm256_loadu_ps(r1.add(j + 8)), y1);
                    _mm256_storeu_ps(py.add(j), y0);
                    _mm256_storeu_ps(py.add(j + 8), y1);
                    j += 16;
                }
                while j + 8 <= $m {
                    let mut y0 = _mm256_loadu_ps(py.add(j));
                    y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), y0);
                    y0 = $madd(b1, _mm256_loadu_ps(r1.add(j)), y0);
                    _mm256_storeu_ps(py.add(j), y0);
                    j += 8;
                }
                while j < $m {
                    let mut v = *py.add(j);
                    v += $x[i] * *r0.add(j);
                    v += $x[i + 1] * *r1.add(j);
                    *py.add(j) = v;
                    j += 1;
                }
                i += 2;
            }
            if i < n {
                let b0 = _mm256_set1_ps($x[i]);
                let r0 = pa.add(i * $m);
                let mut j = 0;
                while j + 8 <= $m {
                    let y0 = $madd(b0, _mm256_loadu_ps(r0.add(j)), _mm256_loadu_ps(py.add(j)));
                    _mm256_storeu_ps(py.add(j), y0);
                    j += 8;
                }
                while j < $m {
                    *py.add(j) += $x[i] * *r0.add(j);
                    j += 1;
                }
            }
        }};
    }

    /// Multiply-then-add (two rounded ops — bit-matches the scalar
    /// `y += x * a`).
    #[inline]
    #[target_feature(enable = "avx")]
    unsafe fn madd_mul_add(a: __m256, b: __m256, c: __m256) -> __m256 {
        _mm256_add_ps(c, _mm256_mul_ps(a, b))
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn vecmat_avx2(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_body!(x, a, m, y, madd_mul_add)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn vecmat_fma(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_body!(x, a, m, y, _mm256_fmadd_ps)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 16 <= n {
            let y0 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))),
            );
            let y1 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j + 8)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j + 8))),
            );
            _mm256_storeu_ps(py.add(j), y0);
            _mm256_storeu_ps(py.add(j + 8), y1);
            j += 16;
        }
        while j + 8 <= n {
            let y0 = _mm256_add_ps(
                _mm256_loadu_ps(py.add(j)),
                _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))),
            );
            _mm256_storeu_ps(py.add(j), y0);
            j += 8;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy_fma(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            let y0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(px.add(j)), _mm256_loadu_ps(py.add(j)));
            _mm256_storeu_ps(py.add(j), y0);
            j += 8;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_avx2(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let va = _mm256_set1_ps(alpha);
        let px = x.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            _mm256_storeu_ps(px.add(j), _mm256_mul_ps(va, _mm256_loadu_ps(px.add(j))));
            j += 8;
        }
        while j < n {
            x[j] *= alpha;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn rms_apply_avx2(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
        let n = x.len();
        let vi = _mm256_set1_ps(inv);
        let (px, pg) = (x.as_ptr(), g.as_ptr());
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 8 <= n {
            // (x * inv) * g, same association as the scalar reference
            let v = _mm256_mul_ps(
                _mm256_mul_ps(_mm256_loadu_ps(px.add(j)), vi),
                _mm256_loadu_ps(pg.add(j)),
            );
            _mm256_storeu_ps(py.add(j), v);
            j += 8;
        }
        while j < n {
            y[j] = x[j] * inv * g[j];
            j += 1;
        }
    }

    /// Degree-6 polynomial `exp` for the fast-math tier: clamp,
    /// range-reduce by `n = round(x * log2(e))` with a two-part ln 2,
    /// Horner with FMA, then scale by `2^n` via exponent-bit arithmetic.
    /// Max observed error vs f64 libm is a few ULP (bounded in tests).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp256(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(88.376_26));
        let x = _mm256_max_ps(x, _mm256_set1_ps(-87.336_54));
        let log2e = _mm256_set1_ps(std::f32::consts::LOG2_E);
        // cvtps rounds to nearest-even (default MXCSR), giving n exactly.
        let e = _mm256_cvtps_epi32(_mm256_mul_ps(x, log2e));
        let n = _mm256_cvtepi32_ps(e);
        let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(0.693_359_4), x);
        let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(-2.121_944_4e-4), r);
        let mut p = _mm256_set1_ps(1.0 / 720.0);
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 120.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 24.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0 / 6.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(0.5));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(1.0));
        let ebits = _mm256_add_epi32(e, _mm256_set1_epi32(127));
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(ebits));
        _mm256_mul_ps(p, pow2)
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn softmax_exp_fma(x: &mut [f32], max: f32) -> f32 {
        let n = x.len();
        let vmax = _mm256_set1_ps(max);
        let px = x.as_mut_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j + 8 <= n {
            let e = exp256(_mm256_sub_ps(_mm256_loadu_ps(px.add(j)), vmax));
            _mm256_storeu_ps(px.add(j), e);
            acc = _mm256_add_ps(acc, e);
            j += 8;
        }
        let mut sum = hsum_ordered(acc);
        while j < n {
            x[j] = (x[j] - max).exp();
            sum += x[j];
            j += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn silu_mul_fma(gate: &mut [f32], up: &[f32]) {
        let n = gate.len();
        let one = _mm256_set1_ps(1.0);
        let pg = gate.as_mut_ptr();
        let pu = up.as_ptr();
        let mut j = 0;
        while j + 8 <= n {
            let g = _mm256_loadu_ps(pg.add(j));
            let e = exp256(_mm256_sub_ps(_mm256_setzero_ps(), g));
            let s = _mm256_div_ps(g, _mm256_add_ps(one, e));
            _mm256_storeu_ps(pg.add(j), _mm256_mul_ps(s, _mm256_loadu_ps(pu.add(j))));
            j += 8;
        }
        while j < n {
            gate[j] = crate::tensor::ops::silu(gate[j]) * up[j];
            j += 1;
        }
    }
}

// ==================================================== aarch64 backends

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON kernels. The canonical 16-element block maps to four 4-lane
    //! registers: accumulators (a0, a1) cover scalar lanes 0..8 and
    //! (a2, a3) lanes 8..16, so the reference lane merge
    //! `lane[j] = acc[j] + acc[8 + j]` is `a0+a2` / `a1+a3` and the
    //! ordered horizontal sum walks the stored lanes left to right.

    use core::arch::aarch64::*;

    #[inline]
    unsafe fn hsum_ordered2(lo: float32x4_t, hi: float32x4_t) -> f32 {
        let mut lane = [0.0f32; 8];
        vst1q_f32(lane.as_mut_ptr(), lo);
        vst1q_f32(lane.as_mut_ptr().add(4), hi);
        let mut s = lane[0];
        for &l in &lane[1..] {
            s += l;
        }
        s
    }

    macro_rules! dot_neon_body {
        ($a:ident, $b:ident, $madd:ident) => {{
            let n = $a.len();
            let blocks = n / 16;
            let (pa, pb) = ($a.as_ptr(), $b.as_ptr());
            let mut a0 = vdupq_n_f32(0.0);
            let mut a1 = vdupq_n_f32(0.0);
            let mut a2 = vdupq_n_f32(0.0);
            let mut a3 = vdupq_n_f32(0.0);
            for i in 0..blocks {
                let o = i * 16;
                a0 = $madd(a0, vld1q_f32(pa.add(o)), vld1q_f32(pb.add(o)));
                a1 = $madd(a1, vld1q_f32(pa.add(o + 4)), vld1q_f32(pb.add(o + 4)));
                a2 = $madd(a2, vld1q_f32(pa.add(o + 8)), vld1q_f32(pb.add(o + 8)));
                a3 = $madd(a3, vld1q_f32(pa.add(o + 12)), vld1q_f32(pb.add(o + 12)));
            }
            let mut s = hsum_ordered2(vaddq_f32(a0, a2), vaddq_f32(a1, a3));
            for i in blocks * 16..n {
                s += $a[i] * $b[i];
            }
            s
        }};
    }

    /// Multiply-then-add (two rounded ops, bit-matching the scalar ref).
    #[inline]
    unsafe fn madd_mul_add(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
        vaddq_f32(acc, vmulq_f32(x, y))
    }

    /// Fused multiply-add for the fast-math tier.
    #[inline]
    unsafe fn madd_fused(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
        vfmaq_f32(acc, x, y)
    }

    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body!(a, b, madd_mul_add)
    }

    pub(super) unsafe fn dot_fma_neon(a: &[f32], b: &[f32]) -> f32 {
        dot_neon_body!(a, b, madd_fused)
    }

    macro_rules! vecmat_neon_body {
        ($x:ident, $a:ident, $m:ident, $y:ident, $madd:ident) => {{
            $y.fill(0.0);
            let py = $y.as_mut_ptr();
            for (i, &xi) in $x.iter().enumerate() {
                let bx = vdupq_n_f32(xi);
                let row = $a.as_ptr().add(i * $m);
                let mut j = 0;
                while j + 4 <= $m {
                    let v = $madd(vld1q_f32(py.add(j)), bx, vld1q_f32(row.add(j)));
                    vst1q_f32(py.add(j), v);
                    j += 4;
                }
                while j < $m {
                    *py.add(j) += xi * *row.add(j);
                    j += 1;
                }
            }
        }};
    }

    pub(super) unsafe fn vecmat_neon(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_neon_body!(x, a, m, y, madd_mul_add)
    }

    pub(super) unsafe fn vecmat_fma_neon(x: &[f32], a: &[f32], m: usize, y: &mut [f32]) {
        vecmat_neon_body!(x, a, m, y, madd_fused)
    }

    pub(super) unsafe fn axpy_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vaddq_f32(vld1q_f32(py.add(j)), vmulq_f32(va, vld1q_f32(px.add(j))));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    pub(super) unsafe fn axpy_fma_neon(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_ptr();
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vfmaq_f32(vld1q_f32(py.add(j)), va, vld1q_f32(px.add(j)));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] += alpha * x[j];
            j += 1;
        }
    }

    pub(super) unsafe fn scale_neon(x: &mut [f32], alpha: f32) {
        let n = x.len();
        let va = vdupq_n_f32(alpha);
        let px = x.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            vst1q_f32(px.add(j), vmulq_f32(va, vld1q_f32(px.add(j))));
            j += 4;
        }
        while j < n {
            x[j] *= alpha;
            j += 1;
        }
    }

    pub(super) unsafe fn rms_apply_neon(x: &[f32], g: &[f32], y: &mut [f32], inv: f32) {
        let n = x.len();
        let vi = vdupq_n_f32(inv);
        let (px, pg) = (x.as_ptr(), g.as_ptr());
        let py = y.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let v = vmulq_f32(vmulq_f32(vld1q_f32(px.add(j)), vi), vld1q_f32(pg.add(j)));
            vst1q_f32(py.add(j), v);
            j += 4;
        }
        while j < n {
            y[j] = x[j] * inv * g[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pt::{check, prop_assert};
    use crate::util::rng::Rng;

    fn f64_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in KernelMode::all() {
            assert_eq!(KernelMode::parse(m.name()), Some(m));
        }
        assert_eq!(KernelMode::parse("ref"), Some(KernelMode::Reference));
        assert_eq!(KernelMode::parse("fma"), Some(KernelMode::SimdFma));
        assert_eq!(KernelMode::parse("nope"), None);
        assert_eq!(KernelMode::default(), KernelMode::Simd);
    }

    #[test]
    fn backend_name_is_stable() {
        let n = backend_name();
        assert!(["scalar", "avx2", "avx2+fma", "neon"].contains(&n), "{n}");
        assert_eq!(n, backend_name());
    }

    /// The tentpole invariant: `Simd` output is bitwise equal to the
    /// scalar reference for every primitive, across lane-remainder
    /// lengths (tails), unaligned starts, and random data.
    #[test]
    fn simd_bit_identical_to_reference() {
        check(40, |rng: &mut Rng| {
            let n = 1 + rng.below(200);
            let m = 1 + rng.below(70);
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            prop_assert(
                dot(KernelMode::Simd, &a, &b).to_bits() == ops::dot(&a, &b).to_bits(),
                "dot bits",
            )?;

            let w = rng.normal_vec(n * m);
            let mut y_ref = vec![0.0f32; m];
            let mut y_simd = vec![0.0f32; m];
            ops::vecmat(&a, &w, m, &mut y_ref);
            vecmat(KernelMode::Simd, &a, &w, m, &mut y_simd);
            prop_assert(bits(&y_ref) == bits(&y_simd), "vecmat bits")?;

            let alpha = rng.normal();
            let mut y2_ref = y_ref.clone();
            let mut y2_simd = y_ref.clone();
            axpy_scalar(alpha, &b[..m.min(n)], &mut y2_ref[..m.min(n)]);
            axpy(KernelMode::Simd, alpha, &b[..m.min(n)], &mut y2_simd[..m.min(n)]);
            prop_assert(bits(&y2_ref) == bits(&y2_simd), "axpy bits")?;

            let g = rng.normal_vec(n);
            let mut r_ref = vec![0.0f32; n];
            let mut r_simd = vec![0.0f32; n];
            ops::rms_norm(&a, &g, &mut r_ref, 1e-5);
            rms_norm(KernelMode::Simd, &a, &g, &mut r_simd, 1e-5);
            prop_assert(bits(&r_ref) == bits(&r_simd), "rms_norm bits")?;

            let mut s_ref = a.clone();
            let mut s_simd = a.clone();
            ops::softmax(&mut s_ref);
            softmax(KernelMode::Simd, &mut s_simd);
            prop_assert(bits(&s_ref) == bits(&s_simd), "softmax bits")?;

            let mut g_ref = a.clone();
            let mut g_simd = a.clone();
            let up = rng.normal_vec(n);
            silu_mul(KernelMode::Reference, &mut g_ref, &up);
            silu_mul(KernelMode::Simd, &mut g_simd, &up);
            prop_assert(bits(&g_ref) == bits(&g_simd), "silu_mul bits")
        });
    }

    #[test]
    fn matmul_modes_match_reference() {
        let mut rng = Rng::new(9);
        let (n, k, m) = (5, 33, 17);
        let a = rng.normal_vec(n * k);
        let b = rng.normal_vec(k * m);
        let mut c_ref = vec![0.0f32; n * m];
        let mut c_simd = vec![0.0f32; n * m];
        ops::matmul(&a, &b, n, k, m, &mut c_ref);
        matmul(KernelMode::Simd, &a, &b, n, k, m, &mut c_simd);
        assert_eq!(bits(&c_ref), bits(&c_simd));
        let mut c_fma = vec![0.0f32; n * m];
        matmul(KernelMode::SimdFma, &a, &b, n, k, m, &mut c_fma);
        for (x, y) in c_ref.iter().zip(&c_fma) {
            assert!((x - y).abs() <= 1e-4 * x.abs().max(1.0));
        }
    }

    fn bits(x: &[f32]) -> Vec<u32> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    /// ULP distance between an f32 and an f64 reference value.
    fn ulp_err(got: f32, want: f64) -> f64 {
        let w = want as f32;
        let ulp = (w.abs().max(f32::MIN_POSITIVE) * f32::EPSILON) as f64;
        ((got as f64) - want).abs() / ulp
    }

    /// SimdFma forward-error bounds vs f64 accumulation: FMA reductions
    /// must stay within C·eps·sum(|terms|) of the f64 result (the
    /// standard sequential-summation bound with headroom; the canonical
    /// blocked order keeps the constant small).
    #[test]
    fn fma_dot_ulp_bounded_vs_f64() {
        check(40, |rng: &mut Rng| {
            let n = 1 + rng.below(600);
            let a = rng.normal_vec(n);
            let b = rng.normal_vec(n);
            let want = f64_dot(&a, &b);
            let got = dot(KernelMode::SimdFma, &a, &b) as f64;
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let bound = (f32::EPSILON as f64) * mag * (8.0 + (n as f64) / 2.0);
            prop_assert((got - want).abs() <= bound, "fma dot exceeds forward-error bound")
        });
    }

    #[test]
    fn fma_vecmat_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(120);
            let m = 1 + rng.below(50);
            let x = rng.normal_vec(n);
            let w = rng.normal_vec(n * m);
            let mut y = vec![0.0f32; m];
            vecmat(KernelMode::SimdFma, &x, &w, m, &mut y);
            for j in 0..m {
                let want: f64 = (0..n).map(|i| x[i] as f64 * w[i * m + j] as f64).sum();
                let mag: f64 = (0..n).map(|i| (x[i] as f64 * w[i * m + j] as f64).abs()).sum();
                let bound = (f32::EPSILON as f64) * mag * (8.0 + (n as f64) / 2.0);
                prop_assert((y[j] as f64 - want).abs() <= bound, "fma vecmat bound")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fma_rms_norm_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let x = rng.normal_vec(n);
            let g = rng.normal_vec(n);
            let mut y = vec![0.0f32; n];
            rms_norm(KernelMode::SimdFma, &x, &g, &mut y, 1e-5);
            let ms: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / n as f64;
            let inv = 1.0 / (ms + 1e-5f64).sqrt();
            for i in 0..n {
                let want = x[i] as f64 * inv * g[i] as f64;
                prop_assert(ulp_err(y[i], want) <= 16.0 + n as f64 / 4.0, "fma rms_norm ulp")?;
            }
            Ok(())
        });
    }

    /// The polynomial exp inside SimdFma softmax must stay within a few
    /// ULP of libm, and the resulting distribution within tight ULPs of
    /// the f64 softmax.
    #[test]
    fn fma_softmax_ulp_bounded_vs_f64() {
        check(20, |rng: &mut Rng| {
            let n = 1 + rng.below(300);
            let x = rng.normal_vec(n);
            let mut got = x.clone();
            softmax(KernelMode::SimdFma, &mut got);
            let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let exps: Vec<f64> = x.iter().map(|&v| ((v as f64) - max).exp()).collect();
            let denom: f64 = exps.iter().sum();
            let s: f32 = got.iter().sum();
            prop_assert((s as f64 - 1.0).abs() < 1e-5, "fma softmax sums to one")?;
            for (i, &e) in exps.iter().enumerate() {
                let want = e / denom;
                // poly-exp (few ULP) + reassociated sum (n/8 chain)
                prop_assert(ulp_err(got[i], want) <= 32.0 + n as f64 / 4.0, "fma softmax ulp")?;
            }
            Ok(())
        });
    }

    #[test]
    fn fma_silu_mul_close_to_reference() {
        let mut rng = Rng::new(11);
        let n = 333;
        let g0 = rng.normal_vec(n);
        let up = rng.normal_vec(n);
        let mut g_ref = g0.clone();
        silu_mul(KernelMode::Reference, &mut g_ref, &up);
        let mut g_fma = g0.clone();
        silu_mul(KernelMode::SimdFma, &mut g_fma, &up);
        for i in 0..n {
            let want = (g0[i] as f64) / (1.0 + (-(g0[i] as f64)).exp()) * up[i] as f64;
            assert!(ulp_err(g_fma[i], want) <= 32.0, "silu ulp at {i}");
            assert!((g_ref[i] - g_fma[i]).abs() <= 1e-5 * g_ref[i].abs().max(1.0));
        }
    }

    /// exp edge cases through the softmax path: large negative inputs
    /// must underflow toward zero without producing NaN/inf, and the
    /// clamp must keep large positives finite.
    #[test]
    fn fma_softmax_extreme_logits_stay_finite() {
        let mut x = vec![1000.0f32, 1001.0, 999.0, -1000.0, 0.0, -87.0, 12.0, -3.0, 5.5];
        softmax(KernelMode::SimdFma, &mut x);
        assert!(x.iter().all(|v| v.is_finite() && *v >= 0.0));
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}
