//! Fig 6 reproduction: Needle-in-a-Haystack heatmap (context length x
//! needle depth), dense vs HATA, on the trained model.
//!
//!     cargo run --release --example needle_haystack

use hata::bench::eval::task_accuracy;
use hata::bench::report::{fmt, Table};
use hata::bench::tasks::TaskKind;
use hata::config::manifest::Manifest;
use hata::config::{preset, Method, ServeConfig};
use hata::kvcache::MethodAux;
use hata::model::{weights::Weights, Model};
use hata::util::rng::Rng;

fn load(serve: &ServeConfig) -> (Model, bool) {
    if let Ok(m) = Manifest::load("artifacts") {
        if let Ok(arts) = m.model("hata-mha") {
            let mut w = Weights::load(&arts.weights, &arts.config).expect("weights");
            if let Some(hw) = arts.hash_weights_for(arts.config.rbit) {
                w.load_hash(hw, &arts.config).expect("hash");
                let aux = MethodAux::build(&arts.config, serve, None, 7);
                return (Model::new(arts.config.clone(), w, aux), true);
            }
        }
    }
    let cfg = preset("hata-mha").unwrap();
    let mut rng = Rng::new(0);
    let w = Weights::random(&cfg, &mut rng);
    (Model::new(cfg, w, MethodAux::default()), false)
}

fn main() {
    let samples: usize =
        std::env::var("HATA_NIAH_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let ctxs = [128usize, 256, 512, 1024];
    let depths = [0.1f64, 0.3, 0.5, 0.7, 0.9];
    for method in [Method::Dense, Method::Hata] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { 48 },
            ..Default::default()
        };
        let (model, trained) = load(&serve);
        let mut t = Table::new(
            &format!(
                "Fig 6: NIAH accuracy heatmap, method={} (trained={trained})",
                method.name()
            ),
            &["ctx\\depth", "0.1", "0.3", "0.5", "0.7", "0.9"],
        );
        for &ctx in &ctxs {
            let mut row = vec![ctx.to_string()];
            for &d in &depths {
                let acc =
                    task_accuracy(&model, &serve, TaskKind::Ns, ctx, samples, 17, Some(d));
                row.push(fmt(100.0 * acc));
            }
            t.row(row);
            eprintln!("[niah] {} ctx={ctx} done", method.name());
        }
        println!("{}", t.render());
        t.write_csv("bench_results", &format!("fig6_{}", method.name())).unwrap();
    }
}
