//! Quickstart: load the trained model from artifacts (or random weights if
//! artifacts are not built yet), run one retrieval prompt under dense and
//! HATA attention, and print both continuations.
//!
//!     cargo run --release --example quickstart

use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::manifest::Manifest;
use hata::config::{preset, Method, ServeConfig};
use hata::kvcache::{MethodAux, SeqKvCache};
use hata::model::{make_selector, sel_ref, tokenizer, weights::Weights, DecodeScratch, Model, SeqState};
use hata::util::rng::Rng;

fn load(serve: &ServeConfig) -> (Model, &'static str) {
    if let Ok(m) = Manifest::load("artifacts") {
        if let Ok(arts) = m.model("hata-mha") {
            let mut w = Weights::load(&arts.weights, &arts.config).expect("weights");
            if let Some(hw) = arts.hash_weights_for(arts.config.rbit) {
                w.load_hash(hw, &arts.config).expect("hash weights");
                let aux = MethodAux::build(&arts.config, serve, None, 7);
                return (Model::new(arts.config.clone(), w, aux), "trained artifacts");
            }
        }
    }
    let cfg = preset("hata-mha").unwrap();
    let mut rng = Rng::new(0);
    let w = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, 7);
    (Model::new(cfg, w, aux), "random weights (run `make artifacts`)")
}

fn main() {
    let corpus = Corpus::new(0);
    let mut rng = Rng::new(11);
    let (prompt, answer) = make_task(TaskKind::Ns, &corpus, &mut rng, 384, Some(0.3));
    println!("expected answer: {answer}\n");
    for method in [Method::Dense, Method::Hata] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { 48 },
            ..Default::default()
        };
        let (model, src) = load(&serve);
        let selector = make_selector(&serve);
        let mut cache = SeqKvCache::new(&model.cfg, &serve);
        let mut state = SeqState::new(&model.cfg);
        let mut scratch = DecodeScratch::new(&model.cfg);
        let out = model.generate(
            &tokenizer::encode(&prompt),
            answer.len(),
            &serve,
            sel_ref(&selector),
            &mut cache,
            &mut state,
            &mut scratch,
        );
        println!(
            "{:>6} ({src}): {:?}  {}",
            method.name(),
            tokenizer::decode(&out),
            if tokenizer::decode(&out) == answer { "✓" } else { "✗" }
        );
    }
}
