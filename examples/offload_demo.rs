//! HATA-off demo (paper Sec 5.3 / Table 3): tiered KV cache with top-k
//! prefetch vs a MagicPIG-style CPU-scoring design, across prefill
//! lengths — prints the modeled time breakdown and the PCIe ledger.
//!
//!     cargo run --release --example offload_demo

use hata::bench::report::{fmt, Table};
use hata::config::preset;
use hata::kvcache::offload::{hata_off, magicpig_off, OffloadRates};

fn main() {
    let rates = OffloadRates::paper_testbed();
    let cfg = preset("mirror-llama2-7b").unwrap();
    let mut t = Table::new(
        "HATA-off vs MagicPIG across prefill lengths (500 decode steps)",
        &["prefill", "hata_prefill_s", "hata_decode_s", "mp_prefill_s", "mp_decode_s", "hata_speedup_total"],
    );
    for prefill in [9_000usize, 18_000, 36_000, 72_000] {
        let budget = ((prefill as f64) * 0.0156) as usize;
        let h = hata_off(&cfg, &rates, prefill, 500, budget);
        let m = magicpig_off(&cfg, &rates, prefill, 500, (prefill as f64 * 0.025) as usize);
        t.row(vec![
            prefill.to_string(),
            fmt(h.prefill_seconds),
            fmt(h.decode_seconds),
            fmt(m.prefill_seconds),
            fmt(m.decode_seconds),
            fmt(m.total() / h.total()),
        ]);
    }
    println!("{}", t.render());
    println!("(cost model: kvcache/offload.rs; PCIe 4.0 x16 effective 25 GB/s, 10us DMA setup)");
    t.write_csv("bench_results", "offload_demo").unwrap();
}
