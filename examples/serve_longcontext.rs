//! END-TO-END DRIVER (DESIGN.md §6): batched serving of long-context
//! retrieval requests through the full coordinator stack — router →
//! continuous-batching engine → HATA attention → KV/code caches — with
//! latency/throughput/accuracy reporting. Results are recorded in
//! EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_longcontext
//!
//! Env: HATA_SERVE_CTX (default 768), HATA_SERVE_N (default 8 requests).

use std::sync::Arc;

use hata::bench::report::{fmt, Table};
use hata::bench::tasks::{make_task, Corpus, TaskKind};
use hata::config::manifest::Manifest;
use hata::config::{preset, Method, ServeConfig};
use hata::coordinator::request::Request;
use hata::coordinator::router::{Policy, Router};
use hata::kvcache::MethodAux;
use hata::model::{tokenizer, weights::Weights, Model};
use hata::util::rng::Rng;
use hata::util::stats::Summary;

fn load(serve: &ServeConfig) -> (Arc<Model>, bool) {
    if let Ok(m) = Manifest::load("artifacts") {
        if let Ok(arts) = m.model("hata-mha") {
            let mut w = Weights::load(&arts.weights, &arts.config).expect("weights");
            if let Some(hw) = arts.hash_weights_for(arts.config.rbit) {
                w.load_hash(hw, &arts.config).expect("hash");
                let aux = MethodAux::build(&arts.config, serve, None, 7);
                return (Arc::new(Model::new(arts.config.clone(), w, aux)), true);
            }
        }
    }
    let cfg = preset("hata-mha").unwrap();
    let mut rng = Rng::new(0);
    let w = Weights::random(&cfg, &mut rng);
    let aux = MethodAux::build(&cfg, serve, None, 7);
    (Arc::new(Model::new(cfg, w, aux)), false)
}

fn main() {
    let ctx: usize =
        std::env::var("HATA_SERVE_CTX").ok().and_then(|v| v.parse().ok()).unwrap_or(768);
    let n: usize = std::env::var("HATA_SERVE_N").ok().and_then(|v| v.parse().ok()).unwrap_or(8);
    let budget = ((ctx as f64) * 0.0625).max(16.0) as usize;
    let kinds = [TaskKind::Ns, TaskKind::Nmk, TaskKind::Vt, TaskKind::Qa];
    let corpus = Corpus::new(0);
    let mut table = Table::new(
        &format!("serve_longcontext: {n} requests, ctx={ctx}, budget={budget}"),
        &["method", "wall_s", "tok_s", "ttft_p50_ms", "ttft_p99_ms", "accuracy_pct", "trained"],
    );
    for method in [Method::Dense, Method::Hata, Method::Quest, Method::Loki] {
        let serve = ServeConfig {
            method,
            budget: if method == Method::Dense { 0 } else { budget },
            max_batch: 4,
            prefill_chunk: 2048,
            ..Default::default()
        };
        let (model, trained) = load(&serve);
        let mut router = Router::new(Arc::clone(&model), serve.clone(), 1, Policy::LeastLoaded);
        let mut rng = Rng::new(5);
        let mut answers = std::collections::BTreeMap::new();
        let t0 = std::time::Instant::now();
        for id in 0..n as u64 {
            let kind = kinds[id as usize % kinds.len()];
            let (prompt, ans) = make_task(kind, &corpus, &mut rng, ctx, None);
            answers.insert(id, ans.clone());
            router.submit(Request {
                id,
                prompt: tokenizer::encode(&prompt),
                max_new_tokens: ans.len(),
                stop_token: None,
                arrival: 0.0,
            });
        }
        let rs = router.drain();
        let wall = t0.elapsed().as_secs_f64();
        let gen: usize = rs.iter().map(|r| r.tokens.len()).sum();
        let mut ttft = Summary::new();
        let mut hits = 0usize;
        for r in &rs {
            ttft.add(r.ttft * 1e3);
            if tokenizer::decode(&r.tokens) == answers[&r.id] {
                hits += 1;
            }
        }
        table.row(vec![
            method.name().to_string(),
            fmt(wall),
            fmt(gen as f64 / wall),
            fmt(ttft.p50()),
            fmt(ttft.p99()),
            fmt(100.0 * hits as f64 / n as f64),
            trained.to_string(),
        ]);
        eprintln!("[serve] {} done in {:.1}s", method.name(), wall);
    }
    println!("{}", table.render());
    table.write_csv("bench_results", "serve_longcontext").unwrap();
}
